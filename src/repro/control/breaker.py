"""Storm-triggered steal circuit-breaker.

``repro.trace.storms`` detects steal storms *offline*: windows where the
balance mechanism degenerated into bulk work migration, paying the nonlocal
penalty on most tasks (the paper's Fig. 4 degraded dynamic runs).
``StormBreaker`` runs the same windowed detector *online* — over the live
executor's streaming counters, not a recorded trace — and acts on it: while
a storm (or a backpressure inline burst) is in progress, stealing is
temporarily throttled by raising the inner governor's depth threshold, or
cut entirely, then re-enabled after a cool-down of quiet windows.

This deliberately bends the paper's balance-over-locality rule (§2.2), but
only transiently and only in the regime where the paper's own evidence says
the rule misfires: when *most* executed tasks in a window are steals, the
backlog is structural (a hot domain, not a momentarily idle one) and every
steal pays the nonlocal penalty without fixing the imbalance.  Once the
cool-down lapses, greedy balance wins again in the limit — same contract as
``AdaptiveSteal``'s idle decay.

The breaker is a ``StealGovernor`` decorator: wrap any inner governor and
install the breaker in its place (``ControlLoop.attach`` does both).  Its
detector reads only ``RuntimeStats`` counter deltas, so it works with event
recording disabled and is deterministic under replay.
"""
from __future__ import annotations

from typing import Optional

from ..runtime import Executor, GreedySteal, StealGovernor, Worker

MODES = ("raise", "block")


class StormBreaker(StealGovernor):
    """Windowed steal-storm detector + governor decorator.

    Under a hierarchical topology the detector gains a *level* dimension:
    windows whose steals are dominated by cross-tier ("remote", level >= 2)
    steals trip a remote-only state first — stealing stays allowed inside a
    socket while the deep links are cut — and only a storm that persists
    (or was never remote-dominated) trips the full breaker.  Cross-level
    storms are thereby detected and broken before the blunt instrument
    engages, at a lower evidence bar (``remote_frac`` < ``steal_frac``):
    a remote steal pays the scaled deep-link penalty, so fewer of them
    justify intervention.

    Parameters
    ----------
    inner:         the governor to decorate; ``ControlLoop.attach`` fills in
                   the executor's current governor when None.
    width:         detector window width in scheduling rounds.
    steal_frac:    steal fraction of executed tasks that trips the breaker.
    inline_frac:   inline (backpressure) fraction that trips it.
    remote_frac:   cross-tier steal fraction that trips the remote-only
                   state (never trips on flat machines, where no steal is
                   remote).
    min_executed:  evidence floor per window (tiny windows never trip).
    cooldown:      windows the breaker stays tripped after the last
                   detection; a storm during cool-down re-arms it.
    mode:          "raise" adds ``boost`` to the inner governor's victim
                   depth threshold while tripped; "block" forbids stealing
                   outright.  The remote-only state applies the same mode,
                   restricted to levels >= 2.
    """

    def __init__(self, inner: StealGovernor | None = None, *,
                 width: int = 8, steal_frac: float = 0.5,
                 inline_frac: float = 0.25, remote_frac: float = 0.25,
                 min_executed: int = 4,
                 cooldown: int = 3, mode: str = "raise", boost: int = 8):
        if width < 1:
            raise ValueError("window width must be >= 1")
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r} (want one of {MODES})")
        self.inner = inner
        self.width = width
        self.steal_frac = steal_frac
        self.inline_frac = inline_frac
        self.remote_frac = remote_frac
        self.min_executed = min_executed
        self.cooldown = cooldown
        self.mode = mode
        self.boost = boost
        self.trips = 0               # distinct full storm episodes
        self.remote_trips = 0        # distinct remote-only episodes
        self._cooldown_left = 0      # windows until stealing re-enables
        self._remote_cooldown_left = 0   # windows until deep links re-enable
        self._last_step = 0
        # (executed, stolen, inline, remote_steals) counter snapshot
        self._seen = (0, 0, 0, 0)

    # -- governor face -------------------------------------------------------
    @property
    def _inner(self) -> StealGovernor:
        return self.inner if self.inner is not None else _GREEDY

    @property
    def tripped(self) -> bool:
        return self._cooldown_left > 0

    @property
    def remote_tripped(self) -> bool:
        """True while cross-tier (level >= 2) stealing is cut; near-tier
        stealing stays governed by the inner governor alone."""
        return self._remote_cooldown_left > 0

    def min_victim_depth(self, worker: Worker) -> Optional[int]:
        base = self._inner.min_victim_depth(worker)
        if not self.tripped:
            return base
        if self.mode == "block" or base is None:
            return None
        return base + self.boost

    def min_victim_depth_at(self, worker: Worker,
                            level: int) -> Optional[int]:
        base = self._inner.min_victim_depth_at(worker, level)
        if self.tripped or (level >= 2 and self.remote_tripped):
            if self.mode == "block" or base is None:
                return None
            return base + self.boost
        return base

    def on_idle(self, worker: Worker) -> None:
        self._inner.on_idle(worker)

    def on_execute(self, worker: Worker, stolen: bool, penalty: float,
                   cost: float = 1.0, level: int = 1) -> None:
        self._inner.on_execute(worker, stolen, penalty, cost, level=level)

    # -- detector face -------------------------------------------------------
    def observe(self, executor: Executor) -> None:
        """Fold the counters accumulated since the last window boundary.

        Call every step (``ControlLoop`` does, via the executor's
        ``step_hook``); it only acts once per ``width`` rounds.
        """
        step = executor.step_count
        if step - self._last_step < self.width:
            return
        self._last_step = step
        s = executor.stats
        now = (s.executed, s.stolen, s.inline_runs, s.remote_steals)
        executed, stolen, inline, remote = (a - b
                                            for a, b in zip(now, self._seen))
        self._seen = now
        self.observe_window(executed, stolen, inline, remote)

    def observe_window(self, executed: int, stolen: int, inline: int,
                       remote: int = 0) -> None:
        """One detector window: trip on a steal storm or an inline burst,
        otherwise let the cool-downs tick down.

        ``remote`` counts the window's cross-tier steals.  A remote-dominated
        storm on a quiet breaker trips only the remote state (deep links cut,
        near stealing preserved); the full breaker engages when a storm
        arrives while already throttling, or when the storm was never
        remote-dominated in the first place.
        """
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
        if self._remote_cooldown_left > 0:
            self._remote_cooldown_left -= 1
        if executed < self.min_executed:
            return
        storm = stolen / executed >= self.steal_frac
        burst = inline / executed >= self.inline_frac
        remote_storm = remote > 0 and remote / executed >= self.remote_frac
        throttling = self._cooldown_left > 0 or self._remote_cooldown_left > 0
        if remote_storm:
            if self._remote_cooldown_left == 0:
                self.remote_trips += 1
            self._remote_cooldown_left = self.cooldown
        if burst or (storm and (throttling or not remote_storm)):
            if self._cooldown_left == 0:
                self.trips += 1
            self._cooldown_left = self.cooldown

    # -- checkpoint surface (repro.spec.BreakerStateSpec) --------------------
    def breaker_state(self) -> dict[str, int]:
        """The warm state a checkpoint must carry to resume mid-cooldown:
        remaining cooldown windows plus the episode counters."""
        return {"cooldown_left": self._cooldown_left,
                "remote_cooldown_left": self._remote_cooldown_left,
                "trips": self.trips, "remote_trips": self.remote_trips}

    def seed_state(self, cooldown_left: int = 0, remote_cooldown_left: int = 0,
                   trips: int = 0, remote_trips: int = 0) -> None:
        """Restore ``breaker_state`` output onto a fresh breaker."""
        self._cooldown_left = int(cooldown_left)
        self._remote_cooldown_left = int(remote_cooldown_left)
        self.trips = int(trips)
        self.remote_trips = int(remote_trips)


_GREEDY = GreedySteal()
