"""Storm-triggered steal circuit-breaker.

``repro.trace.storms`` detects steal storms *offline*: windows where the
balance mechanism degenerated into bulk work migration, paying the nonlocal
penalty on most tasks (the paper's Fig. 4 degraded dynamic runs).
``StormBreaker`` runs the same windowed detector *online* — over the live
executor's streaming counters, not a recorded trace — and acts on it: while
a storm (or a backpressure inline burst) is in progress, stealing is
temporarily throttled by raising the inner governor's depth threshold, or
cut entirely, then re-enabled after a cool-down of quiet windows.

This deliberately bends the paper's balance-over-locality rule (§2.2), but
only transiently and only in the regime where the paper's own evidence says
the rule misfires: when *most* executed tasks in a window are steals, the
backlog is structural (a hot domain, not a momentarily idle one) and every
steal pays the nonlocal penalty without fixing the imbalance.  Once the
cool-down lapses, greedy balance wins again in the limit — same contract as
``AdaptiveSteal``'s idle decay.

The breaker is a ``StealGovernor`` decorator: wrap any inner governor and
install the breaker in its place (``ControlLoop.attach`` does both).  Its
detector reads only ``RuntimeStats`` counter deltas, so it works with event
recording disabled and is deterministic under replay.
"""
from __future__ import annotations

from typing import Optional

from ..runtime import Executor, GreedySteal, StealGovernor, Worker

MODES = ("raise", "block")


class StormBreaker(StealGovernor):
    """Windowed steal-storm detector + governor decorator.

    Parameters
    ----------
    inner:         the governor to decorate; ``ControlLoop.attach`` fills in
                   the executor's current governor when None.
    width:         detector window width in scheduling rounds.
    steal_frac:    steal fraction of executed tasks that trips the breaker.
    inline_frac:   inline (backpressure) fraction that trips it.
    min_executed:  evidence floor per window (tiny windows never trip).
    cooldown:      windows the breaker stays tripped after the last
                   detection; a storm during cool-down re-arms it.
    mode:          "raise" adds ``boost`` to the inner governor's victim
                   depth threshold while tripped; "block" forbids stealing
                   outright.
    """

    def __init__(self, inner: StealGovernor | None = None, *,
                 width: int = 8, steal_frac: float = 0.5,
                 inline_frac: float = 0.25, min_executed: int = 4,
                 cooldown: int = 3, mode: str = "raise", boost: int = 8):
        if width < 1:
            raise ValueError("window width must be >= 1")
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r} (want one of {MODES})")
        self.inner = inner
        self.width = width
        self.steal_frac = steal_frac
        self.inline_frac = inline_frac
        self.min_executed = min_executed
        self.cooldown = cooldown
        self.mode = mode
        self.boost = boost
        self.trips = 0               # distinct storm episodes
        self._cooldown_left = 0      # windows until stealing re-enables
        self._last_step = 0
        self._seen = (0, 0, 0)       # (executed, stolen, inline) snapshot

    # -- governor face -------------------------------------------------------
    @property
    def _inner(self) -> StealGovernor:
        return self.inner if self.inner is not None else _GREEDY

    @property
    def tripped(self) -> bool:
        return self._cooldown_left > 0

    def min_victim_depth(self, worker: Worker) -> Optional[int]:
        base = self._inner.min_victim_depth(worker)
        if not self.tripped:
            return base
        if self.mode == "block" or base is None:
            return None
        return base + self.boost

    def on_idle(self, worker: Worker) -> None:
        self._inner.on_idle(worker)

    def on_execute(self, worker: Worker, stolen: bool, penalty: float,
                   cost: float = 1.0) -> None:
        self._inner.on_execute(worker, stolen, penalty, cost)

    # -- detector face -------------------------------------------------------
    def observe(self, executor: Executor) -> None:
        """Fold the counters accumulated since the last window boundary.

        Call every step (``ControlLoop`` does, via the executor's
        ``step_hook``); it only acts once per ``width`` rounds.
        """
        step = executor.step_count
        if step - self._last_step < self.width:
            return
        self._last_step = step
        s = executor.stats
        now = (s.executed, s.stolen, s.inline_runs)
        executed, stolen, inline = (a - b for a, b in zip(now, self._seen))
        self._seen = now
        self.observe_window(executed, stolen, inline)

    def observe_window(self, executed: int, stolen: int, inline: int) -> None:
        """One detector window: trip on a steal storm or an inline burst,
        otherwise let the cool-down tick down."""
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
        if executed < self.min_executed:
            return
        storm = stolen / executed >= self.steal_frac
        burst = inline / executed >= self.inline_frac
        if storm or burst:
            if self._cooldown_left == 0:
                self.trips += 1
            self._cooldown_left = self.cooldown


_GREEDY = GreedySteal()
