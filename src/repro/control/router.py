"""Cost-aware routing: pick submit domains by estimated backlog time.

The paper routes a task to the domain that owns its data and lets the steal
scan fix imbalance after the fact (§2.2: balance over locality, applied at
*dequeue* time).  Under heavy-tailed task costs that is too late: a queue
that is short in *items* can be the longest in *work*, and round-robin (or
home) routing keeps feeding it.  ``CostRouter`` moves the balance decision
to *submit* time, where it is free — re-routing a task before it is
enqueued migrates no data, while fixing the same imbalance later via a
steal pays the nonlocal penalty.

The estimate is the classic join-shortest-work heuristic: a domain's
backlog time is its queued cost (``DomainQueues.queue_costs``, maintained
O(1) per enqueue/dequeue) divided by the number of workers pinned to it.
Queued cost measures drain *time* exactly when grabs deliver a fixed cost
budget per round — i.e. under ``BatchGovernor``'s budgeted continuous
batching, the configuration ``ControlLoop.full`` wires up.  (Without
batching this executor serves one item per worker-round whatever it costs,
and depth, not cost, is the wait; the two controllers are designed as a
pair, not as independent toggles.)
Homed tasks stay home unless the home's backlog exceeds the best domain's
by more than the spill threshold — i.e. a task is only sent away from its
data when the queueing-delay gap is worth more than the nonlocal access it
will pay (the same θ-style trade the ``AdaptiveSteal`` governor prices on
the dequeue side).  With ``measured=True`` that threshold is not a static
hint but the governor's live ``penalty_estimate`` (``AdaptiveSteal`` /
``trace.MeasuredPenalty``, unwrapped through a ``StormBreaker`` decorator):
the router and the governor then price the *same* nonlocal cost from the
same measurements, submit-side and dequeue-side respectively
(``repro.spec.RouterSpec(spill="measured")``).
"""
from __future__ import annotations

import math
from typing import Optional

from ..runtime import Executor, Task


class CostRouter:
    """Route submissions to the domain with the least estimated backlog time.

    Under a hierarchical topology (the bound executor carries a
    ``repro.topology.DistanceMatrix``), a homed task's spill candidates are
    considered nearest tier first and each tier's threshold is scaled by
    its link distance: spilling within the home socket asks the flat gap,
    spilling across the socket (or pod) must pay proportionally more —
    within-socket relief is exhausted before work leaves the socket, the
    submit-side mirror of the queues' nearest-first steal scan.

    Parameters
    ----------
    spill_penalty:  backlog-time gap (in cost units) a homed task's home
                    queue must exceed before the task is re-routed to the
                    cheapest domain; 0 makes every task join the shortest
                    work queue, ``None`` never spills homed tasks (pure
                    locality routing for homed, cost routing for homeless).
    measured:       price the spill threshold from the bound executor's
                    governor ``penalty_estimate`` instead of the static
                    ``spill_penalty`` hint (which remains the fallback for
                    governors that measure nothing, e.g. ``GreedySteal``).
    breaker_aware:  consult the bound executor's ``StormBreaker`` (when its
                    governor is one): while the full breaker is tripped,
                    homed tasks are never spilled (routing must not re-feed
                    the storm the breaker is quenching); while only the
                    remote state is tripped, spills stay within the home's
                    nearest tier.
    """

    def __init__(self, spill_penalty: Optional[float] = 4.0,
                 measured: bool = False, breaker_aware: bool = False):
        self.spill_penalty = spill_penalty
        self.measured = measured
        self.breaker_aware = breaker_aware
        self._ex: Optional[Executor] = None
        self._workers_per_domain: list[int] = []
        self.routed = 0
        self.spilled = 0         # homed tasks sent away from their home
        self.remote_spills = 0   # spills that crossed a topology tier >= 2

    def bind(self, executor: Executor) -> "CostRouter":
        """Point the router at ``executor``'s queues/worker layout (done by
        ``ControlLoop.attach``; call directly for standalone use)."""
        self._ex = executor
        counts = [0] * executor.num_domains
        for w in executor.pool:
            counts[w.domain] += 1
        self._workers_per_domain = counts
        return self

    def backlog_time(self, domain: int) -> float:
        """Estimated wait a task routed to ``domain`` sees: queued cost over
        pinned workers (inf for domains no worker serves — they only drain
        via steals, so the router never feeds them directly)."""
        if self._ex is None:
            raise RuntimeError("CostRouter is not bound to an executor")
        workers = self._workers_per_domain[domain]
        if workers == 0:
            return math.inf
        return self._ex.queues.cost(domain) / workers

    def spill_threshold(self) -> Optional[float]:
        """The live spill threshold: the governor's measured penalty
        estimate when ``measured`` (unwrapping a ``StormBreaker``'s inner
        governor), else the static ``spill_penalty`` hint."""
        if self.measured and self._ex is not None:
            gov = self._ex.governor
            gov = getattr(gov, "inner", None) or gov    # breaker decoration
            est = getattr(gov, "penalty_estimate", None)
            if est is not None:
                return float(est)
        return self.spill_penalty

    def _breaker_states(self) -> tuple[bool, bool]:
        """(full_tripped, remote_tripped) of the bound executor's breaker
        when ``breaker_aware``; (False, False) otherwise or when the
        governor is no breaker."""
        if not self.breaker_aware or self._ex is None:
            return False, False
        gov = self._ex.governor
        return (bool(getattr(gov, "tripped", False)),
                bool(getattr(gov, "remote_tripped", False)))

    def route(self, task: Task) -> int:
        """Submit domain for ``task``: least-backlog, home-sticky up to
        ``spill_threshold()`` (the ``Executor(router=...)`` callback).

        Hierarchical topologies spill nearest-first with distance-scaled
        thresholds; ``breaker_aware`` suspends spilling while the breaker
        quenches a storm (remote-only trips only suspend cross-tier
        spills).  Homeless tasks always join the least-backlog domain.
        """
        backlogs = [self.backlog_time(d)
                    for d in range(self._ex.num_domains)]
        best = min(range(len(backlogs)), key=lambda d: (backlogs[d], d))
        self.routed += 1
        home = task.home
        if not (0 <= home < len(backlogs) and backlogs[home] < math.inf):
            return best
        tripped, remote_tripped = self._breaker_states()
        if tripped:
            return home
        spill = self.spill_threshold()
        topo = getattr(self._ex, "topology", None)
        if topo is None or not topo.hierarchical:
            if spill is None or backlogs[home] - backlogs[best] <= spill:
                return home
            self.spilled += 1
            return best
        if spill is None:
            return home
        for level in range(1, topo.num_levels + 1):
            if level >= 2 and remote_tripped:
                break
            cands = [d for d in topo.peers(home, level)
                     if backlogs[d] < math.inf]
            if not cands:
                continue
            cand = min(cands, key=lambda d: (backlogs[d], d))
            # the gap must beat the spill threshold scaled by the link the
            # task's data would be accessed across — within-socket relief
            # is exhausted before work leaves the socket
            if (backlogs[home] - backlogs[cand]
                    > spill * topo.distance(home, cand)):
                self.spilled += 1
                if level >= 2:
                    self.remote_spills += 1
                return cand
        return home
