"""Cost-aware routing: pick submit domains by estimated backlog time.

The paper routes a task to the domain that owns its data and lets the steal
scan fix imbalance after the fact (§2.2: balance over locality, applied at
*dequeue* time).  Under heavy-tailed task costs that is too late: a queue
that is short in *items* can be the longest in *work*, and round-robin (or
home) routing keeps feeding it.  ``CostRouter`` moves the balance decision
to *submit* time, where it is free — re-routing a task before it is
enqueued migrates no data, while fixing the same imbalance later via a
steal pays the nonlocal penalty.

The estimate is the classic join-shortest-work heuristic: a domain's
backlog time is its queued cost (``DomainQueues.queue_costs``, maintained
O(1) per enqueue/dequeue) divided by the number of workers pinned to it.
Queued cost measures drain *time* exactly when grabs deliver a fixed cost
budget per round — i.e. under ``BatchGovernor``'s budgeted continuous
batching, the configuration ``ControlLoop.full`` wires up.  (Without
batching this executor serves one item per worker-round whatever it costs,
and depth, not cost, is the wait; the two controllers are designed as a
pair, not as independent toggles.)
Homed tasks stay home unless the home's backlog exceeds the best domain's
by more than the spill threshold — i.e. a task is only sent away from its
data when the queueing-delay gap is worth more than the nonlocal access it
will pay (the same θ-style trade the ``AdaptiveSteal`` governor prices on
the dequeue side).  With ``measured=True`` that threshold is not a static
hint but the governor's live ``penalty_estimate`` (``AdaptiveSteal`` /
``trace.MeasuredPenalty``, unwrapped through a ``StormBreaker`` decorator):
the router and the governor then price the *same* nonlocal cost from the
same measurements, submit-side and dequeue-side respectively
(``repro.spec.RouterSpec(spill="measured")``).
"""
from __future__ import annotations

import math
from typing import Optional

from ..runtime import Executor, Task


class CostRouter:
    """Route submissions to the domain with the least estimated backlog time.

    Parameters
    ----------
    spill_penalty:  backlog-time gap (in cost units) a homed task's home
                    queue must exceed before the task is re-routed to the
                    cheapest domain; 0 makes every task join the shortest
                    work queue, ``None`` never spills homed tasks (pure
                    locality routing for homed, cost routing for homeless).
    measured:       price the spill threshold from the bound executor's
                    governor ``penalty_estimate`` instead of the static
                    ``spill_penalty`` hint (which remains the fallback for
                    governors that measure nothing, e.g. ``GreedySteal``).
    """

    def __init__(self, spill_penalty: Optional[float] = 4.0,
                 measured: bool = False):
        self.spill_penalty = spill_penalty
        self.measured = measured
        self._ex: Optional[Executor] = None
        self._workers_per_domain: list[int] = []
        self.routed = 0
        self.spilled = 0     # homed tasks sent away from their home

    def bind(self, executor: Executor) -> "CostRouter":
        """Point the router at ``executor``'s queues/worker layout (done by
        ``ControlLoop.attach``; call directly for standalone use)."""
        self._ex = executor
        counts = [0] * executor.num_domains
        for w in executor.pool:
            counts[w.domain] += 1
        self._workers_per_domain = counts
        return self

    def backlog_time(self, domain: int) -> float:
        """Estimated wait a task routed to ``domain`` sees: queued cost over
        pinned workers (inf for domains no worker serves — they only drain
        via steals, so the router never feeds them directly)."""
        if self._ex is None:
            raise RuntimeError("CostRouter is not bound to an executor")
        workers = self._workers_per_domain[domain]
        if workers == 0:
            return math.inf
        return self._ex.queues.cost(domain) / workers

    def spill_threshold(self) -> Optional[float]:
        """The live spill threshold: the governor's measured penalty
        estimate when ``measured`` (unwrapping a ``StormBreaker``'s inner
        governor), else the static ``spill_penalty`` hint."""
        if self.measured and self._ex is not None:
            gov = self._ex.governor
            gov = getattr(gov, "inner", None) or gov    # breaker decoration
            est = getattr(gov, "penalty_estimate", None)
            if est is not None:
                return float(est)
        return self.spill_penalty

    def route(self, task: Task) -> int:
        """Submit domain for ``task``: least-backlog, home-sticky up to
        ``spill_threshold()`` (the ``Executor(router=...)`` callback)."""
        backlogs = [self.backlog_time(d)
                    for d in range(self._ex.num_domains)]
        best = min(range(len(backlogs)), key=lambda d: (backlogs[d], d))
        self.routed += 1
        home = task.home
        if 0 <= home < len(backlogs) and backlogs[home] < math.inf:
            spill = self.spill_threshold()
            if spill is None or backlogs[home] - backlogs[best] <= spill:
                return home
            self.spilled += 1
        return best
