"""repro.control — the online control plane over the locality runtime.

PR 1 (``repro.runtime``) built the paper's mechanism — locality queues,
steal scans, governors — and PR 2 (``repro.trace``) the observability —
recorded traces, replay, storm detectors.  Both leave the *cross-domain
policy* static: the steal threshold, the batch size, and the routing rule
are fixed at construction, while production arrival traces change shape
minute-to-minute.  This package closes the loop: controllers watch the
live runtime and adjust those three knobs online.

Paper-concept map (Wittmann & Hager, 2010), continuing the tables in
``repro/runtime/__init__.py`` and ``repro/trace/__init__.py``:

  paper concept (§)                      control object
  -------------------------------------  ---------------------------------
  balance over locality at dequeue       ``CostRouter``: the same balance
  (§2.2 steal scan)                      decision moved to *submit* time,
                                         priced in queued cost — re-routing
                                         before enqueue migrates no data,
                                         stealing after the fact does
  victim = next nonempty queue (§2.2)    ``cost_weighted`` steal order in
                                         ``runtime.DomainQueues``: victim =
                                         most queued *work*, not most items
  one task per grab (§2.1 tasking)       ``BatchGovernor`` + the executor's
                                         batch grabs: one scheduling round
                                         serves a whole same-queue batch,
                                         sized to a service budget
  Fig. 4 degraded dynamic runs           ``StormBreaker``: the trace-layer
  (steal storms)                         storm detector run online, wired
                                         back into the governor as a
                                         circuit-breaker with cool-down
  (composition)                          ``ControlLoop``: splices all three
                                         into an ``Executor``'s hook points

Every controller reads only deterministic executor state (queue costs,
counter deltas, the step clock), so controlled runs record and replay
bit-identically (``benchmarks/control_plane.py`` A/Bs controlled vs
uncontrolled policies on recorded traces).

Usage::

    from repro.control import ControlLoop
    from repro.runtime import Executor

    ex = ControlLoop.full().attach(
        Executor(4, steal_penalty=lambda t, w: 4.0 * t.cost))
    ...  # submit/step/run_until_drained as usual; policy adapts online
"""
from .batching import BatchGovernor
from .breaker import StormBreaker
from .loop import ControlLoop
from .router import CostRouter

__all__ = ["BatchGovernor", "ControlLoop", "CostRouter", "StormBreaker"]
