"""Continuous batching: adapt the executor's batch-grab size online.

A batch grab amortizes one scheduling round over several same-queue tasks
(``Executor(batch=...)``): the bigger the batch, the higher the per-round
throughput — but also the longer one grab monopolizes a worker, so batches
must shrink when tasks get expensive (long prefills) and may grow when
tasks are cheap.  ``BatchGovernor`` closes that loop from measurements: it
tracks an EMA of the per-task service actually delivered per batch (task
cost plus any steal penalty — the deterministic service proxy used across
the repo, so controlled runs stay replayable) and sizes the next batch to
fit a fixed per-grab service budget:

    size = clamp(round(target_service / per_task_service), batch_min, batch_cap)

The governor also exposes ``target_service`` as the grab's cost ``budget``
(the executor stops draining before a batch's summed cost exceeds it), so
every grab delivers ≈ ``target_service`` cost units per round regardless of
the cost mix — cheap tasks run wide, one long prefill fills the budget
alone.  That constant cost-per-round drain rate is what makes a queue's
total queued cost an honest backlog-*time* estimate, i.e. what makes
``CostRouter``'s join-shortest-work routing correct.

Steal penalties inflate measured service, so batches automatically thin
out exactly when grabs start migrating work — the batching analogue of the
``AdaptiveSteal`` throttle.
"""
from __future__ import annotations

_MIN_SERVICE = 1e-9


class BatchGovernor:
    """Adaptive batch-size policy for ``Executor(batch=...)``.

    Implements the executor's batch-policy duck type: a ``size`` property
    read before each grab and an ``on_batch(n_tasks, service)`` feedback
    call after it.

    Parameters
    ----------
    target_service:  service budget (cost units) one grab should fill.
    batch_min/cap:   hard clamp on the adapted size.
    ema:             smoothing of the per-task service estimate in (0, 1].
    init_size:       batch size before the first measurement.
    """

    def __init__(self, target_service: float = 8.0, batch_min: int = 1,
                 batch_cap: int = 8, ema: float = 0.25, init_size: int = 1):
        if target_service <= 0:
            raise ValueError("target_service must be positive")
        if not 1 <= batch_min <= batch_cap:
            raise ValueError("need 1 <= batch_min <= batch_cap")
        if not 0.0 < ema <= 1.0:
            raise ValueError("ema must be in (0, 1]")
        self.target_service = target_service
        self.batch_min = batch_min
        self.batch_cap = batch_cap
        self.ema = ema
        self._size = min(max(init_size, batch_min), batch_cap)
        self._per_task: float | None = None
        self.batches = 0
        self.tasks = 0

    @property
    def size(self) -> int:
        """Batch-grab limit for the next grab."""
        return self._size

    @property
    def budget(self) -> float:
        """Cost budget per grab (the executor's budgeted drain bound)."""
        return self.target_service

    @property
    def service_estimate(self) -> float | None:
        """EMA of per-task service over observed batches (None pre-warmup)."""
        return self._per_task

    def on_batch(self, n_tasks: int, service: float) -> None:
        """Feed one executed grab: ``n_tasks`` served, ``service`` total
        cost+penalty delivered.  Called by the executor after every grab."""
        if n_tasks < 1:
            return
        per = max(service / n_tasks, _MIN_SERVICE)
        self._per_task = (per if self._per_task is None else
                          (1 - self.ema) * self._per_task + self.ema * per)
        self._size = min(max(round(self.target_service / self._per_task),
                             self.batch_min), self.batch_cap)
        self.batches += 1
        self.tasks += n_tasks
