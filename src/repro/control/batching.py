"""Continuous batching: adapt the executor's batch-grab size online.

A batch grab amortizes one scheduling round over several same-queue tasks
(``Executor(batch=...)``): the bigger the batch, the higher the per-round
throughput — but also the longer one grab monopolizes a worker, so batches
must shrink when tasks get expensive (long prefills) and may grow when
tasks are cheap.  ``BatchGovernor`` closes that loop from measurements: it
tracks an EMA of the per-task service actually delivered per batch (task
cost plus any steal penalty — the deterministic service proxy used across
the repo, so controlled runs stay replayable) and sizes the next batch to
fit a fixed per-grab service budget:

    size = clamp(round(target_service / per_task_service), batch_min, batch_cap)

The governor also exposes ``target_service`` as the grab's cost ``budget``
(the executor stops draining before a batch's summed cost exceeds it), so
every grab delivers ≈ ``target_service`` cost units per round regardless of
the cost mix — cheap tasks run wide, one long prefill fills the budget
alone.  That constant cost-per-round drain rate is what makes a queue's
total queued cost an honest backlog-*time* estimate, i.e. what makes
``CostRouter``'s join-shortest-work routing correct.

Steal penalties inflate measured service, so batches automatically thin
out exactly when grabs start migrating work — the batching analogue of the
``AdaptiveSteal`` throttle.
"""
from __future__ import annotations

_MIN_SERVICE = 1e-9


class BatchGovernor:
    """Adaptive batch-size policy for ``Executor(batch=...)``.

    Implements the executor's batch-policy duck type: a ``size`` property
    read before each grab and an ``on_batch(n_tasks, service)`` feedback
    call after it.

    With ``per_domain=True`` the governor keeps one service EMA *per source
    queue* under the same global ``target_service`` budget — a domain
    serving long prefills grabs thin batches while a domain of cheap tasks
    grabs wide ones, instead of one global estimate splitting the
    difference and mis-sizing both.  The executor then reads
    ``size_for(domain)`` per grab and feeds back
    ``on_batch(n_tasks, service, domain)``; the global EMA keeps updating
    alongside (it sizes domains never yet observed, and remains the
    ``service_estimate``/``size`` surface).

    Parameters
    ----------
    target_service:  service budget (cost units) one grab should fill.
    batch_min/cap:   hard clamp on the adapted size.
    ema:             smoothing of the per-task service estimate in (0, 1].
    init_size:       batch size before the first measurement.
    per_domain:      size grabs from each queue by that queue's own EMA.
    """

    per_domain: bool

    def __init__(self, target_service: float = 8.0, batch_min: int = 1,
                 batch_cap: int = 8, ema: float = 0.25, init_size: int = 1,
                 per_domain: bool = False):
        if target_service <= 0:
            raise ValueError("target_service must be positive")
        if not 1 <= batch_min <= batch_cap:
            raise ValueError("need 1 <= batch_min <= batch_cap")
        if not 0.0 < ema <= 1.0:
            raise ValueError("ema must be in (0, 1]")
        self.target_service = target_service
        self.batch_min = batch_min
        self.batch_cap = batch_cap
        self.ema = ema
        self.per_domain = per_domain
        self._size = min(max(init_size, batch_min), batch_cap)
        self._per_task: float | None = None
        self._domain_per_task: dict[int, float] = {}
        self.batches = 0
        self.tasks = 0

    @property
    def size(self) -> int:
        """Batch-grab limit for the next grab (the global estimate)."""
        return self._size

    def size_for(self, domain: int) -> int:
        """Grab limit for a batch sourced from ``domain``: sized by that
        domain's own service EMA when ``per_domain`` and one exists, else
        the global ``size``."""
        if not self.per_domain:
            return self._size
        per = self._domain_per_task.get(domain)
        if per is None:
            return self._size
        return self._clamp(per)

    @property
    def budget(self) -> float:
        """Cost budget per grab (the executor's budgeted drain bound)."""
        return self.target_service

    @property
    def service_estimate(self) -> float | None:
        """EMA of per-task service over observed batches (None pre-warmup)."""
        return self._per_task

    def domain_service_estimates(self) -> dict[int, float]:
        """Per-domain per-task service EMAs (domain -> estimate); empty
        unless ``per_domain`` has observed grabs.  Snapshot surface for
        ``repro.spec.BatchStateSpec``."""
        return dict(self._domain_per_task)

    def seed_state(self, service_estimate: float | None = None,
                   size: int | None = None,
                   domain_estimates: dict[int, float] | None = None) -> None:
        """Restore learned state onto a fresh governor (checkpoint/restore
        counterpart of ``service_estimate``/``size``/
        ``domain_service_estimates``)."""
        if service_estimate is not None:
            self._per_task = float(service_estimate)
        if size is not None:
            self._size = min(max(int(size), self.batch_min), self.batch_cap)
        if domain_estimates:
            self._domain_per_task.update(
                {int(d): float(v) for d, v in domain_estimates.items()})

    def _clamp(self, per_task: float) -> int:
        return min(max(round(self.target_service / per_task),
                       self.batch_min), self.batch_cap)

    def on_batch(self, n_tasks: int, service: float,
                 domain: int = -1) -> None:
        """Feed one executed grab: ``n_tasks`` served, ``service`` total
        cost+penalty delivered, ``domain`` the queue the grab drained (only
        used when ``per_domain``).  Called by the executor after every
        grab."""
        if n_tasks < 1:
            return
        per = max(service / n_tasks, _MIN_SERVICE)
        self._per_task = (per if self._per_task is None else
                          (1 - self.ema) * self._per_task + self.ema * per)
        self._size = self._clamp(self._per_task)
        if self.per_domain and domain >= 0:
            prev = self._domain_per_task.get(domain)
            self._domain_per_task[domain] = (
                per if prev is None else (1 - self.ema) * prev + self.ema * per)
        self.batches += 1
        self.tasks += n_tasks
