"""The control loop: wire routing, batching, and the breaker into a runtime.

``ControlLoop`` is the composition point of the control plane.  It owns up
to three controllers — a ``CostRouter`` (submit side), a ``BatchGovernor``
(grab size), and a ``StormBreaker`` (steal throttle) — and splices them
into an ``Executor``'s existing hook points:

    router   -> Executor.router        (consulted on submit(domain=None))
    batcher  -> Executor.batch         (read per grab, fed per batch)
    breaker  -> Executor.governor      (decorating the previous governor)
    loop     -> Executor.step_hook     (the breaker's detector heartbeat)

Everything the loop reads (queue costs, counter deltas, the step clock) is
deterministic state of the cooperative executor, so a *controlled* run is
exactly as replayable as an uncontrolled one: record it with
``repro.trace.TraceRecorder`` and replay with a factory that attaches a
fresh, identically-configured ``ControlLoop`` — the replayed
``RuntimeStats`` reproduce the recorded ones bit-for-bit.

Attach order matters when recording: attach the control loop *before* the
trace recorder snapshots meta (the breaker replaces the governor object).
"""
from __future__ import annotations

from typing import Optional

from ..runtime import Executor
from .batching import BatchGovernor
from .breaker import StormBreaker
from .router import CostRouter


class ControlLoop:
    """Compose cost routing + continuous batching + the steal breaker."""

    def __init__(self, router: Optional[CostRouter] = None,
                 batcher: Optional[BatchGovernor] = None,
                 breaker: Optional[StormBreaker] = None):
        self.router = router
        self.batcher = batcher
        self.breaker = breaker
        self._ex: Optional[Executor] = None

    @classmethod
    def full(cls, *, spill_penalty: float = 4.0, target_service: float = 8.0,
             batch_cap: int = 8, width: int = 8, cooldown: int = 3,
             mode: str = "raise") -> "ControlLoop":
        """The all-controllers configuration used by the benchmarks."""
        return cls(router=CostRouter(spill_penalty=spill_penalty),
                   batcher=BatchGovernor(target_service=target_service,
                                         batch_cap=batch_cap),
                   breaker=StormBreaker(width=width, cooldown=cooldown,
                                        mode=mode))

    def attach(self, executor: Executor) -> Executor:
        """Splice the controllers into ``executor`` and return it
        (chainable, mirroring ``TraceRecorder.attach``)."""
        if self._ex is not None:
            raise RuntimeError("ControlLoop is already attached; "
                               "use one loop per executor")
        if self.router is not None:
            self.router.bind(executor)
            executor.router = self.router.route
        if self.batcher is not None:
            executor.batch = self.batcher
        if self.breaker is not None:
            if self.breaker.inner is None:
                self.breaker.inner = executor.governor
            executor.governor = self.breaker
        prev_hook = executor.step_hook

        def on_step(ex: Executor, _prev=prev_hook) -> None:
            if self.breaker is not None:
                self.breaker.observe(ex)
            if _prev is not None:
                _prev(ex)

        executor.step_hook = on_step
        self._ex = executor
        return executor

    @property
    def executor(self) -> Executor:
        if self._ex is None:
            raise RuntimeError("ControlLoop is not attached to an executor")
        return self._ex

    def governor_state(self):
        """Export the attached executor's learned governor θ state as a
        serializable ``repro.spec.GovernorStateSpec`` (the breaker
        decoration is unwrapped), or None when the effective governor
        carries no learned state (greedy/none kinds).  The declarative
        checkpoint surface for *controlled* systems — pair with
        ``repro.spec.checkpoint(executor)`` for the full spec."""
        from ..spec import GovernorStateSpec, SpecError  # lazy: spec↔control

        try:
            return GovernorStateSpec.from_governor(self.executor.governor)
        except SpecError:
            return None

    def snapshot(self) -> dict[str, float]:
        """Controller state for logging/benchmark JSON."""
        out: dict[str, float] = {}
        if self.router is not None:
            out["routed"] = self.router.routed
            out["spilled"] = self.router.spilled
            out["remote_spills"] = self.router.remote_spills
        if self.batcher is not None:
            out["batch_size"] = self.batcher.size
            out["batches"] = self.batcher.batches
            if self.batcher.service_estimate is not None:
                out["service_estimate"] = round(
                    self.batcher.service_estimate, 4)
        if self.breaker is not None:
            out["breaker_trips"] = self.breaker.trips
            out["breaker_tripped"] = int(self.breaker.tripped)
            out["breaker_remote_trips"] = self.breaker.remote_trips
        return out
