"""Pipeline parallelism over the pod axis (GPipe-style, shard_map + ppermute).

The multi-pod mesh's "pod" axis is the slow (DCN) tier.  Data parallelism
over pods all-reduces the full gradient across pods every step; pipelining
instead keeps weight shards pod-local and moves only microbatch activations
between stages — the paper's locality rule (keep bandwidth-hungry traffic
inside the locality domain, let only the thin stream cross) applied to the
parallelism layout itself.

Implementation: the classic collective_permute pipeline. Layer stacks are
sharded over the `pod` axis (stage s owns layers [s*L/P, (s+1)*L/P)); each
of M microbatches flows stage-to-stage; the steady-state loop runs
M + P - 1 ticks, each tick = one stage compute + one ppermute handoff.
Bubble fraction = (P-1)/(M+P-1).

`pipeline_wire_bytes` provides the napkin model used in §Perf to decide
between DP-over-pods and PP-over-pods for a given arch.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipelined_apply(layer_fn: Callable, stage_params, x_mb: jnp.ndarray,
                    axis: str = "pod", gather_output: bool = True):
    """Run M microbatches through P pipeline stages over mesh axis `axis`.

    layer_fn(params_slice, x) -> x : one stage's computation (already
      vmapped/scanned over the stage's own layers).
    stage_params: stage-sharded params (leading axis = stage, sharded over
      `axis` inside the enclosing shard_map).
    x_mb: (M, mb, ...) microbatched inputs, replicated across stages.

    Returns (M, mb, ...) outputs (valid on the LAST stage; other stages
    hold garbage that the caller discards — standard GPipe SPMD form).
    """
    n_stage = jax.lax.axis_size(axis)
    stage = jax.lax.axis_index(axis)
    m = x_mb.shape[0]
    ticks = m + n_stage - 1
    fwd = [(i, (i + 1) % n_stage) for i in range(n_stage)]

    def tick(carry, t):
        state, outputs = carry          # state: (mb, ...) in-flight activation
        # stage 0 injects microbatch t (if any remain); others use incoming
        inject = jnp.where(t < m, t, m - 1)
        x_in = jnp.where(stage == 0, x_mb[inject], state)
        y = layer_fn(stage_params, x_in)
        # last stage records finished microbatch (t - (P-1))
        out_idx = t - (n_stage - 1)
        record = (stage == n_stage - 1) & (out_idx >= 0)
        outputs = jax.lax.cond(
            record,
            lambda o: jax.lax.dynamic_update_index_in_dim(
                o, y, jnp.maximum(out_idx, 0), 0),
            lambda o: o, outputs)
        # hand activations to the next stage
        state = jax.lax.ppermute(y, axis, fwd)
        return (state, outputs), None

    state0 = jnp.zeros_like(x_mb[0])
    outputs0 = jnp.zeros_like(x_mb)
    (_, outputs), _ = jax.lax.scan(tick, (state0, outputs0),
                                   jnp.arange(ticks))
    if gather_output:
        # results exist only on the last stage (zeros elsewhere): a psum is
        # exactly the broadcast-from-last-stage
        outputs = jax.lax.psum(outputs, axis)
    return outputs


def bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    return (num_stages - 1) / (num_microbatches + num_stages - 1)


def pipeline_wire_bytes(param_bytes: float, act_bytes_per_mb: float,
                        num_stages: int, num_microbatches: int) -> dict:
    """Napkin model: inter-pod traffic per step, DP-over-pods vs PP-over-pods.

    DP: 2x param_bytes gradient all-reduce across pods.
    PP: one activation handoff per microbatch per stage boundary
        (forward + backward), no cross-pod gradient traffic.
    """
    dp = 2.0 * param_bytes
    pp = 2.0 * act_bytes_per_mb * num_microbatches * (num_stages - 1) / num_stages
    return {"dp_bytes": dp, "pp_bytes": pp,
            "pp_wins": pp < dp,
            "bubble": bubble_fraction(num_stages, num_microbatches)}
