"""Fault tolerance: elastic re-meshing, straggler mitigation, restart policy.

On a 1000+-node fleet the failure model is: a pod loses chips (or a whole
pod drops), training must resume on the survivors from the last checkpoint.
Because the paper's schedule builder makes work→domain assignment an
explicit, recomputable artifact, elasticity is a *pure re-assignment*:

  1. detect the degraded device set (here: injected via DeviceSet),
  2. rebuild the mesh from survivors (largest rectangle that keeps the
     model axis intact — TP shards cannot be dropped, DP replicas can),
  3. re-run the locality schedule builder over the new domain set,
  4. restore the latest checkpoint with the new shardings and continue.

Straggler mitigation follows the paper's steal rule: the host-side loaders
and the serving router already steal from the slowest domain; for the
synchronous train step, the mitigation is micro-rebalancing the *data*
assignment (slow host gets fewer shards next epoch).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from ..core.assignment import Assignment, build_assignment


@dataclasses.dataclass(frozen=True)
class DeviceSet:
    """A (possibly degraded) fleet: pods x (data x model) grid per pod."""
    pods: int
    data: int
    model: int
    failed: frozenset[tuple[int, int, int]] = frozenset()  # (pod, d, m)

    @property
    def total(self) -> int:
        return self.pods * self.data * self.model - len(self.failed)


def plan_elastic_mesh(devs: DeviceSet) -> dict:
    """Largest healthy mesh after failures.

    Rule: the model axis must stay whole (a TP shard loss kills its data
    row); any data row containing a failure is dropped from the mesh; a pod
    that loses every row is dropped.  Returns the new mesh shape plus which
    rows survive — the input to re-sharding and schedule rebuild.
    """
    surviving_rows: list[tuple[int, int]] = []
    for p in range(devs.pods):
        for d in range(devs.data):
            row_ok = all((p, d, m) not in devs.failed for m in range(devs.model))
            if row_ok:
                surviving_rows.append((p, d))
    if not surviving_rows:
        raise RuntimeError("no healthy data rows survive — cannot re-mesh")
    pods_alive = sorted({p for p, _ in surviving_rows})
    # equalize rows per pod (SPMD needs a rectangular mesh)
    rows_per_pod = min(sum(1 for q, _ in surviving_rows if q == p)
                       for p in pods_alive)
    kept = []
    for p in pods_alive:
        rows = [r for r in surviving_rows if r[0] == p][:rows_per_pod]
        kept.extend(rows)
    return {
        "mesh_shape": (len(pods_alive), rows_per_pod, devs.model),
        "axes": ("pod", "data", "model"),
        "kept_rows": kept,
        "dropped_rows": [r for r in surviving_rows if r not in kept],
        "lost_fraction": 1.0 - (len(pods_alive) * rows_per_pod * devs.model
                                ) / (devs.pods * devs.data * devs.model),
    }


def rebuild_schedule(task_homes: np.ndarray, task_cost: np.ndarray,
                     old_domains: int, new_domains: int) -> Assignment:
    """Re-run the locality schedule for a changed domain count.

    Tasks homed in vanished domains become free (-1) and are placed by the
    balance rule; everything else keeps locality — the paper's scheduler
    makes elasticity cheap by construction.
    """
    homes = np.where(task_homes < new_domains, task_homes, -1)
    return build_assignment(homes, task_cost, new_domains)


@dataclasses.dataclass
class StragglerMonitor:
    """EWMA per-domain step times; flags domains slower than k x median and
    proposes a data rebalance (shed fraction proportional to slowdown)."""
    num_domains: int
    alpha: float = 0.2
    threshold: float = 1.3
    _ewma: Optional[np.ndarray] = None

    def update(self, step_times: Sequence[float]) -> dict:
        t = np.asarray(step_times, dtype=np.float64)
        if self._ewma is None:
            self._ewma = t.copy()
        else:
            self._ewma = (1 - self.alpha) * self._ewma + self.alpha * t
        med = float(np.median(self._ewma))
        ratio = self._ewma / max(med, 1e-9)
        stragglers = np.flatnonzero(ratio > self.threshold)
        rebalance = {int(d): float(min(0.5, 1.0 - 1.0 / ratio[d]))
                     for d in stragglers}
        return {"stragglers": stragglers.tolist(),
                "shed_fraction": rebalance,
                "ewma": self._ewma.copy()}
