"""Logical-axis sharding rules -> NamedSharding, MaxText-style.

Model code annotates activations/weights with *logical* axis names
("batch", "seq", "heads", "ffn", "experts", ...); a ``ShardingRules`` table
maps those to physical mesh axes.  Outside a mesh context every annotation
is a no-op, so the same model code runs in CPU smoke tests and in the
512-device dry-run.

The rules encode the distribution strategy of DESIGN.md §6:
  batch   -> (pod, data)      data parallelism (+ pod axis when multi-pod)
  heads/ffn/experts/vocab -> model   tensor/expert parallelism
  seq_q   -> model            sequence parallelism for attention when the
                              head count does not divide the model axis
  kv_seq  -> model            flash-decode style sequence-sharded KV caches
  fsdp    -> data             weight sharding over the data axis (FSDP)
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh
    rules: dict[str, Optional[tuple[str, ...] | str]]

    def spec(self, *logical: Optional[str]) -> P:
        axes = []
        for name in logical:
            if name is None:
                axes.append(None)
                continue
            phys = self.rules.get(name, None)
            axes.append(phys)
        return P(*axes)

    def sharding(self, *logical: Optional[str]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


def make_rules(mesh: Mesh, fsdp: bool = False,
               shard_heads: bool = True) -> ShardingRules:
    """Build the rule table for a (pod?, data, model) mesh."""
    axes = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    batch = batch_axes if len(batch_axes) > 1 else (batch_axes[0] if batch_axes else None)
    model = "model" if "model" in axes else None
    rules: dict[str, Optional[tuple[str, ...] | str]] = {
        "batch": batch,
        "seq": None,                    # activations not seq-sharded by default
        # sequence-parallel attention only when heads cannot shard (both map
        # to the model axis, so exactly one of them may be active)
        "seq_q": None if shard_heads else model,
        "kv_seq": model,                # sequence-sharded KV cache (decode)
        "heads": model if shard_heads else None,
        "kv_heads": None,               # replicated (kv_heads < model axis)
        "ffn": model,
        "experts": model,
        "vocab": model,
        "lru": model,
        "lru_blocks": model,
        "qheads": model if shard_heads else None,
        "rwkv_ffn": model,
        "zero": ("data" if "data" in axes else None),
        "embed": None,                  # d_model replicated on activations
        "fsdp": ("data" if (fsdp and "data" in axes) else None),
    }
    return ShardingRules(mesh=mesh, rules=rules)


_STATE = threading.local()


def current_rules() -> Optional[ShardingRules]:
    return getattr(_STATE, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[ShardingRules]):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield
    finally:
        _STATE.rules = prev


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Annotate an intermediate with logical axes; no-op without rules."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(logical):
        raise ValueError(f"rank {x.ndim} vs logical {logical}")
    return jax.lax.with_sharding_constraint(x, rules.sharding(*logical))
