"""Collective helpers: compressed all-reduce, LSE combine, halo exchange.

These are the explicitly-scheduled collectives used where we control
communication by hand (shard_map regions: the pipeline-parallel stage loop,
the distributed stencil, flash-decode).  Inside plain SPMD jit the XLA
partitioner owns the collectives; gradient "compression" there is achieved
by keeping grads in bf16 (see train_step.py).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def compressed_psum(x: jnp.ndarray, axis: str, *,
                    compression: str = "bf16",
                    error_state: jnp.ndarray | None = None):
    """psum with on-the-wire compression + error feedback.

    compression:
      "none" — plain psum.
      "bf16" — cast to bf16 before the reduce (2x wire saving, unbiased-ish).
      "int8" — per-tensor scale quantization with error feedback: the
               quantization residual is returned and should be added to the
               NEXT step's tensor (standard EF-SGD), keeping the update
               unbiased over time.

    Returns (reduced_f32, new_error_state).
    """
    if compression == "none":
        return jax.lax.psum(x.astype(jnp.float32), axis), error_state
    if compression == "bf16":
        return jax.lax.psum(x.astype(jnp.bfloat16), axis).astype(jnp.float32), \
            error_state
    if compression == "int8":
        xf = x.astype(jnp.float32)
        if error_state is not None:
            xf = xf + error_state
        # sync a single global scale first (a scalar pmax — negligible wire
        # cost) so every member quantizes on the same grid and the int32
        # sum dequantizes exactly
        scale = jax.lax.pmax(
            jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12), axis) / 127.0
        q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
        err = xf - q.astype(jnp.float32) * scale
        total_q = jax.lax.psum(q.astype(jnp.int32), axis)
        return total_q.astype(jnp.float32) * scale, err
    raise ValueError(f"unknown compression {compression!r}")


def lse_combine(partial_out: jnp.ndarray, partial_max: jnp.ndarray,
                partial_sum: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Flash-decode combine: merge per-shard attention partials over `axis`.

    partial_out: (..., d) = sum_j exp(s_j - m) v_j   (local)
    partial_max: (...,)   = m                        (local max logit)
    partial_sum: (...,)   = sum_j exp(s_j - m)       (local)
    """
    g_max = jax.lax.pmax(partial_max, axis)
    alpha = jnp.exp(partial_max - g_max)
    num = jax.lax.psum(partial_out * alpha[..., None], axis)
    den = jax.lax.psum(partial_sum * alpha, axis)
    return num / jnp.maximum(den[..., None], 1e-37)


def ring_halo_exchange(local: jnp.ndarray, axis: str):
    """(prev_plane, next_plane) for 1D domain decomposition (Dirichlet)."""
    n = jax.lax.axis_size(axis)
    idx = jax.lax.axis_index(axis)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    bwd = [(i, (i - 1) % n) for i in range(n)]
    up = jax.lax.ppermute(local[-1], axis, fwd)
    down = jax.lax.ppermute(local[0], axis, bwd)
    up = jnp.where(idx == 0, jnp.zeros_like(up), up)
    down = jnp.where(idx == n - 1, jnp.zeros_like(down), down)
    return up, down


def reduce_scatter_then_all_gather(x: jnp.ndarray, axis: str,
                                   update: Callable[[jnp.ndarray], jnp.ndarray]):
    """Decomposed all-reduce: reduce-scatter → local update → all-gather.

    The canonical overlap-friendly form of a gradient reduction + optimizer
    update (ZeRO-style): each member updates only its 1/n slice, halving
    wire traffic vs all-reduce + replicated update and letting XLA overlap
    the two collectives with the update math.
    """
    n = jax.lax.axis_size(axis)
    scattered = jax.lax.psum_scatter(x, axis, scatter_dimension=0,
                                     tiled=True)
    updated = update(scattered)
    return jax.lax.all_gather(updated, axis, axis=0, tiled=True)
