"""The online locality-aware task executor.

``Executor`` is the generic, online form of the paper's scheduling layer:
tasks arrive dynamically (``submit``), are sorted into per-domain FIFO
queues by their locality tag, and a team of domain-pinned workers serves
them local-first with a pluggable steal scan and steal governor.  The
bounded submission pool reproduces OpenMP tasking semantics (§2.1): when
the pool is full the submitter executes queued tasks itself before
enqueueing more, so in-flight work never exceeds ``pool_cap``.

Workers are stepped cooperatively in a fixed round-robin order, which makes
every run deterministic for a given seed — the repo-wide discrete stand-in
for parallel threads (ordering, not timing, is what scheduling controls).
"""
from __future__ import annotations

import dataclasses
import itertools
from time import perf_counter_ns
from typing import Any, Callable, Optional, Sequence

import numpy as np

from .adaptive import GreedySteal, StealGovernor
from .events import EventLog, ReferenceEventLog
from .metrics import MetricsRecorder
from .queues import DomainQueues
from .workers import Worker, WorkerPool


@dataclasses.dataclass
class Task:
    """One unit of work: an opaque payload plus its locality tag.

    ``home`` is the domain whose memory holds the task's data (page
    placement in the paper, KV-cache residency in serving); -1 means the
    task has no affinity anywhere yet ("first touch" happens on execution).
    ``cost`` is an abstract local execution cost used by governors and
    benchmarks, not a wall-clock promise.
    """

    uid: int
    payload: Any = None
    home: int = -1
    cost: float = 1.0


Handler = Callable[[Task, Worker], Any]
BatchHandler = Callable[[list, Worker], list]    # (tasks, worker) -> results
PenaltyFn = Callable[[Task, Worker], float]
SubmitHook = Callable[[Task, int, int], None]   # (task, routed_domain, step)
Router = Callable[[Task], int]                  # task -> submit domain
StepHook = Callable[["Executor"], None]         # fired after each step()


def _default_handler(task: Task, worker: Worker) -> Any:
    return task.payload(worker) if callable(task.payload) else task.payload


class Executor:
    """Online multi-worker executor over per-domain locality queues.

    Parameters
    ----------
    num_domains:        number of locality domains (queues).
    worker_domains:     domain of each worker, in wid order; defaults to one
                        worker per domain.  Every domain should be covered
                        by a worker unless stealing can reach it.
    handler:            ``(task, worker) -> result``; non-None results are
                        collected and returned by ``run_until_drained``.
                        Defaults to calling the payload if it is callable.
    pool_cap:           bound on queued-but-unrun tasks (§2.1); ``None``
                        disables backpressure.
    steal_order:        "cyclic" (paper §2.2), "longest", "random", or
                        "cost_weighted" (victim = most queued cost).
    governor:           a ``StealGovernor``; default ``GreedySteal``.
    steal_penalty:      ``(task, worker) -> cost`` charged on steals (e.g.
                        re-prefill tokens); accounted in the metrics.
    seed:               drives the executor's RNG (used by random stealing).
    submit_hook:        optional ``(task, routed_domain, step)`` callback fired
                        as each task is enqueued — the recording surface used
                        by ``repro.trace.TraceRecorder`` to capture a
                        replayable submission trace.
    router:             optional ``task -> domain`` routing policy consulted
                        on ``submit(task, domain=None)`` *before* the default
                        home/round-robin rule (``repro.control.CostRouter``
                        plugs in here).  The router sees ``task.home`` and
                        may keep or override it.
    batch:              batch-grab limit per ``_attempt``: an int (static
                        limit, default 1 = the PR-1 behaviour) or any object
                        with a ``size`` property and an
                        ``on_batch(n_tasks, service)`` method (an adaptive
                        policy, e.g. ``repro.control.BatchGovernor``).  After
                        a worker's dequeue picks a source queue, up to
                        ``batch-1`` more tasks are drained from that same
                        queue and executed in one grab (continuous batching:
                        one scheduling round serves a whole batch).  A policy
                        may also expose a ``budget`` (float): the grab then
                        stops before exceeding that much summed task cost
                        (token-budget batching).  A policy with a true
                        ``per_domain`` attribute is sized per source queue
                        (``size_for(domain)``) and fed with the source
                        domain (``on_batch(n_tasks, service, domain)``).
    batch_handler:      ``(tasks, worker) -> results`` called with each grab's
                        task list (length 1..batch).  When None, ``handler``
                        is called per task.  Results align with tasks;
                        non-None entries are collected.
    step_hook:          optional ``(executor) -> None`` fired at the end of
                        every ``step()`` — the control plane's drive point
                        (``repro.control.ControlLoop`` plugs in here).
    topology:           optional ``repro.topology.DistanceMatrix`` arranging
                        the domains in a distance tree.  Hierarchical
                        matrices make every steal scan nearest-first (the
                        configured ``steal_order`` applies within a tier),
                        scale each steal's penalty by the link distance
                        actually crossed, ask the governor for per-level
                        depth thresholds (``min_victim_depth_at``), and
                        count cross-tier steals as ``remote_steals``.  A
                        flat matrix (or None, the default) reproduces the
                        pre-topology behaviour bit-for-bit.
    profiler:           optional ``repro.obs.HotPathProfiler``.  When
                        attached, the executor wraps its four hot decision
                        sites — submit-route, steal-scan, batch-grab,
                        event-append — in ``perf_counter_ns`` timers and
                        feeds the elapsed time to ``profiler.add``.  The
                        profiler is passive (it observes wall clock, never
                        a decision), so profiled runs keep bit-identical
                        ``RuntimeStats`` and replays; with the default
                        ``None`` the timers are skipped entirely.
    fast:               selects the hot-path implementation.  ``True`` (the
                        default) uses the incremental eligibility structures
                        in ``DomainQueues`` and the columnar ``EventLog``;
                        ``False`` runs the pre-rewrite reference scan and
                        the object-per-event ``ReferenceEventLog``.  The two
                        are bit-identical (same stats, same event sequence,
                        same RNG draws) — the slow arm exists as the
                        executable specification for the
                        ``benchmarks.scheduler_overhead`` fast_vs_slow
                        equivalence gate.
    depth_sample_stride: record the per-domain queue-depth sample every
                        N-th scheduling round (default 1 = every round, the
                        original behaviour).  Depth sampling is O(domains)
                        per round; million-task benchmark drives raise the
                        stride to keep it off the hot path.  Counters in
                        ``RuntimeStats`` are unaffected.
    """

    def __init__(self, num_domains: int,
                 worker_domains: Sequence[int] | None = None, *,
                 handler: Handler | None = None,
                 pool_cap: Optional[int] = 256,
                 steal_order: str = "cyclic",
                 governor: StealGovernor | None = None,
                 steal_penalty: PenaltyFn | None = None,
                 seed: int = 0,
                 record_events: bool = True,
                 event_maxlen: int = 65536,
                 submit_hook: SubmitHook | None = None,
                 router: Router | None = None,
                 batch: Any = 1,
                 batch_handler: BatchHandler | None = None,
                 step_hook: StepHook | None = None,
                 topology: Any = None,
                 profiler: Any = None,
                 fast: bool = True,
                 depth_sample_stride: int = 1):
        self.num_domains = num_domains
        self.seed = seed
        self.rng = np.random.default_rng(seed)
        self.topology = topology
        self.fast = fast
        # hoisted out of the per-dequeue steal_scan region: tier count when
        # hierarchical (per-level governor thresholds apply), else 0
        self._hier_levels = (topology.num_levels
                            if topology is not None and topology.hierarchical
                            else 0)
        self.queues = DomainQueues(num_domains, steal_order=steal_order,
                                   rng=self.rng, topology=topology,
                                   fast=fast)
        if worker_domains is None:
            worker_domains = list(range(num_domains))
        self.pool = WorkerPool(worker_domains)
        for w in self.pool:
            if not 0 <= w.domain < num_domains:
                raise ValueError(f"{w!r} outside {num_domains} domains")
        self.handler = handler or _default_handler
        self.pool_cap = pool_cap
        self.governor = governor or GreedySteal()
        self.steal_penalty = steal_penalty
        self.metrics = MetricsRecorder(depth_stride=depth_sample_stride)
        log_cls = EventLog if fast else ReferenceEventLog
        self.events = log_cls(event_maxlen) if record_events else None
        self.submit_hook = submit_hook
        self.router = router
        self.batch = batch
        self.batch_handler = batch_handler
        self.step_hook = step_hook
        self.profiler = profiler
        # the declarative configuration this executor was built from, when
        # constructed via repro.spec (``RuntimeSpec.build`` stamps it here);
        # trace headers embed it so a recorded run fully names its system.
        # Raw-kwarg construction (this __init__ called directly) is the thin
        # deprecated path and leaves it None.
        self.spec = None
        self.results: list[Any] = []
        self._uids = itertools.count()
        self._rr = 0
        self._step = 0
        # bound-method alias: ``queues`` is created here and never swapped,
        # so the per-dequeue attribute walk can be paid once
        self._dequeue = self.queues.dequeue

    @property
    def governor(self):
        return self._governor

    @governor.setter
    def governor(self, gov) -> None:
        # governors are swappable mid-run (the control loop attaches its
        # breaker this way), so the hot-path shortcut below is recomputed on
        # every assignment: a governor that inherits the base
        # ``min_victim_depth`` unchanged is the pure constant-1 probe
        # (GreedySteal), and ``_attempt`` may skip the Python call per
        # dequeue without observable difference — the base probe reads no
        # state and mutates none
        self._governor = gov
        self._greedy_probe = (type(gov).min_victim_depth
                              is StealGovernor.min_victim_depth)

    # -- submission side ----------------------------------------------------
    def make_task(self, payload: Any = None, home: int = -1,
                  cost: float = 1.0) -> Task:
        return Task(uid=next(self._uids), payload=payload, home=home, cost=cost)

    def next_round_robin(self) -> int:
        """Next submit domain in round-robin order, skipping hot domains.

        A domain whose queue depth exceeds 2x the mean depth is skipped (its
        turn is forfeited, not deferred), so round-robin routing cannot keep
        force-feeding a backlogged domain while others idle.  At most one
        pass is made: since not every depth can exceed twice the mean, an
        eligible domain always exists, and with balanced queues this
        degenerates to the plain cycle.
        """
        sizes = self.queues.queue_sizes()
        cap = 2.0 * sum(sizes) / len(sizes)
        for _ in range(self.num_domains):
            d = self._rr % self.num_domains
            self._rr += 1
            if sizes[d] <= cap:
                break
        return d

    def submit(self, task: Task, domain: int | None = None) -> None:
        """Route ``task`` into a domain queue, applying backpressure.

        ``domain=None`` asks the ``router`` (when one is attached), else
        routes to the task's home domain, or round-robin for homeless tasks.
        When the pool is full, the submitter executes queued tasks inline
        (greedily, ignoring the governor — the §2.1 "submitting thread is
        used for processing tasks" rule) until a slot frees up, so the pool
        bound is a hard invariant.
        """
        if domain is None:
            # repro: allow[wall-clock] sanctioned profiler site (submit_route): timer around a decision, never an input to it
            t0 = perf_counter_ns() if self.profiler is not None else 0
            if self.router is not None:
                domain = int(self.router(task))
            elif task.home >= 0:
                domain = task.home
            else:
                domain = self.next_round_robin()
            if self.profiler is not None:
                # repro: allow[wall-clock] sanctioned profiler site (submit_route): elapsed-time read feeds only HotPathProfiler
                self.profiler.add("submit_route", perf_counter_ns() - t0)
        if not 0 <= domain < self.num_domains:
            raise ValueError(f"domain {domain} out of range")
        while self.pool_cap is not None and len(self.queues) >= self.pool_cap:
            if not self._attempt(self.pool[0], inline=True):
                break
        self.queues.enqueue(task, domain)
        self.metrics.on_submit(len(self.queues))
        self._emit("submit", worker=-1, domain=domain, task_uid=task.uid,
                   cost=task.cost)
        if self.submit_hook is not None:
            self.submit_hook(task, domain, self._step)

    # -- execution side -----------------------------------------------------
    def step(self) -> int:
        """One scheduling round: every worker attempts one grab (up to
        ``batch`` tasks from a single queue).  Returns the number of tasks
        executed.  Interleave with ``submit`` for online (arrival-driven)
        operation."""
        self._step += 1
        n = sum(self._attempt(w) for w in self.pool)
        if self.metrics.wants_depths(self._step):
            self.metrics.sample_depths(self._step, self.queues.queue_sizes())
        if self.step_hook is not None:
            self.step_hook(self)
        return n

    def run_until_drained(self) -> list[Any]:
        """Step until all queues are empty; returns (and clears) the
        accumulated non-None handler results, in completion order."""
        stalled = 0
        while len(self.queues):
            if self.step() == 0:
                stalled += 1
                if stalled > 10_000:
                    raise RuntimeError(
                        "executor stalled: tasks queued in domains no worker "
                        f"may serve (sizes={self.queues.queue_sizes()}, "
                        f"workers={[w.domain for w in self.pool]})")
            else:
                stalled = 0
        out, self.results = self.results, []
        return out

    @property
    def batch_max(self) -> int:
        """Current effective batch-grab limit (>= 1)."""
        size = getattr(self.batch, "size", self.batch)
        return max(int(size), 1)

    def _batch_limit(self, domain: int) -> int:
        """The grab limit for a batch sourced from ``domain``: a batch
        policy exposing ``size_for(domain)`` (per-queue sizing, e.g.
        ``BatchGovernor(per_domain=True)``) is consulted per source queue;
        anything else falls back to the global ``batch_max``."""
        size_for = getattr(self.batch, "size_for", None)
        if size_for is not None:
            return max(int(size_for(domain)), 1)
        return self.batch_max

    def _attempt(self, worker: Worker, inline: bool = False) -> int:
        """One grab by ``worker``: dequeue (local-first, governed steal),
        then drain up to ``batch_max - 1`` more tasks from the same source
        queue and execute the batch.  Returns the number of tasks executed
        (0 when nothing was eligible).  Inline (backpressure) grabs stay
        single-task: the submitter only helps enough to free one slot."""
        # repro: allow[wall-clock] sanctioned profiler site (steal_scan): timer around the dequeue, never an input to it
        t0 = perf_counter_ns() if self.profiler is not None else 0
        if inline:
            got = self._dequeue(worker.domain)
        elif self._greedy_probe and not self._hier_levels:
            # base-contract governor (GreedySteal): the probe is the pure
            # constant 1, so skip the per-dequeue Python call entirely
            got = self._dequeue(worker.domain, True, 1)
        else:
            mv = self._governor.min_victim_depth(worker)
            if mv is None:
                got = self._dequeue(worker.domain, False)
            else:
                if self._hier_levels:
                    # per-level thresholds: the governor prices each tier
                    # separately (AdaptiveSteal's per-level θ, the breaker's
                    # remote cut); a scalar-only governor repeats its one
                    # threshold at every tier via the base contract.
                    mv = [self._governor.min_victim_depth_at(worker, lv)
                          for lv in range(1, self._hier_levels + 1)]
                got = self._dequeue(worker.domain, True, mv)
        if self.profiler is not None:
            # repro: allow[wall-clock] sanctioned profiler site (steal_scan): elapsed-time read feeds only HotPathProfiler
            self.profiler.add("steal_scan", perf_counter_ns() - t0)
        if got is None:
            worker.stats.idle_polls += 1
            self.metrics.on_idle()
            self._governor.on_idle(worker)
            self._emit("idle", worker=worker.wid, domain=worker.domain,
                       task_uid=-1)
            return 0
        tasks: list[Task] = [got.item]
        if not inline:
            limit = self._batch_limit(got.domain)
            if limit > 1:
                # repro: allow[wall-clock] sanctioned profiler site (batch_grab): timer around the drain, never an input to it
                t0 = perf_counter_ns() if self.profiler is not None else 0
                tasks += self.queues.drain(
                    got.domain, limit - 1,
                    budget=getattr(self.batch, "budget", None),
                    spent=got.item.cost)
                if self.profiler is not None:
                    # repro: allow[wall-clock] sanctioned profiler site (batch_grab): elapsed-time read feeds only HotPathProfiler
                    self.profiler.add("batch_grab", perf_counter_ns() - t0)
        stolen = got.stolen
        # a steal's penalty is scaled by the link distance it crossed
        # (1.0 for flat/no topology — bit-identical to the uniform-hop rule)
        penalties = [float(self.steal_penalty(t, worker)) * got.distance
                     if stolen and self.steal_penalty is not None else 0.0
                     for t in tasks]
        if self.batch_handler is not None:
            results = list(self.batch_handler(tasks, worker))
            if len(results) != len(tasks):
                raise ValueError(
                    f"batch_handler returned {len(results)} results "
                    f"for {len(tasks)} tasks")
        else:
            results = [self.handler(t, worker) for t in tasks]
        kind = "inline" if inline else ("steal" if stolen else "run")
        remote = stolen and got.level >= 2
        for task, penalty, result in zip(tasks, penalties, results):
            local = not stolen and task.home == worker.domain
            worker.stats.executed += 1
            worker.stats.local += int(local)
            worker.stats.stolen += int(stolen)
            self.metrics.on_execute(local, stolen, penalty, inline,
                                    remote=remote)
            self._governor.on_execute(worker, stolen, penalty, task.cost,
                                     level=got.level)
            self._emit(kind, worker=worker.wid, domain=worker.domain,
                       task_uid=task.uid, src_domain=got.domain,
                       cost=task.cost, penalty=penalty)
            if result is not None:
                self.results.append(result)
        on_batch = getattr(self.batch, "on_batch", None)
        if on_batch is not None and not inline:
            service = sum(t.cost for t in tasks) + sum(penalties)
            if getattr(self.batch, "per_domain", False):
                on_batch(len(tasks), service, got.domain)
            else:
                on_batch(len(tasks), service)
        return len(tasks)

    def _emit(self, kind: str, worker: int, domain: int, task_uid: int,
              src_domain: int = -1, cost: float = 0.0,
              penalty: float = 0.0) -> None:
        if self.events is not None:
            if self.profiler is not None:
                # repro: allow[wall-clock] sanctioned profiler site (event_append): timer around the emit, never an input to it
                t0 = perf_counter_ns()
                self.events.emit(self._step, kind, worker, domain, task_uid,
                                 src_domain, cost, penalty)
                # repro: allow[wall-clock] sanctioned profiler site (event_append): elapsed-time read feeds only HotPathProfiler
                self.profiler.add("event_append", perf_counter_ns() - t0)
            else:
                self.events.emit(self._step, kind, worker, domain, task_uid,
                                 src_domain, cost, penalty)

    # -- introspection ------------------------------------------------------
    @property
    def stats(self):
        return self.metrics.stats

    @property
    def step_count(self) -> int:
        """Scheduling rounds run so far — the discrete makespan proxy."""
        return self._step

    def __len__(self) -> int:
        return len(self.queues)
