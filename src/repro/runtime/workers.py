"""Workers: the runtime's consumer threads.

A ``Worker`` is the online analogue of one pinned OpenMP thread: it has an
identity (``wid``) and a locality domain it is bound to (the paper's
``ld_ID`` map).  The executor steps workers cooperatively in a fixed
round-robin order — a deterministic stand-in for parallel hardware threads
(ordering, not wall-clock timing, is what the scheduling layer controls),
matching the discrete-event style used across this repo.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence


@dataclasses.dataclass
class WorkerStats:
    executed: int = 0
    local: int = 0
    stolen: int = 0
    idle_polls: int = 0


class Worker:
    """One consumer bound to a locality domain."""

    def __init__(self, wid: int, domain: int):
        self.wid = wid
        self.domain = domain
        self.stats = WorkerStats()

    def __repr__(self) -> str:
        return f"Worker(wid={self.wid}, domain={self.domain})"


class WorkerPool:
    """A fixed team of workers, iterated in wid order every scheduling round."""

    def __init__(self, domain_of_worker: Sequence[int]):
        if not domain_of_worker:
            raise ValueError("need at least one worker")
        self.workers = [Worker(wid, int(d)) for wid, d in enumerate(domain_of_worker)]

    @classmethod
    def uniform(cls, num_domains: int, workers_per_domain: int = 1) -> "WorkerPool":
        """Pinned layout: workers [0..k) on domain 0, [k..2k) on domain 1, …
        (the paper's core→LD map, ``topology.ld_id_map``)."""
        return cls([d for d in range(num_domains)
                    for _ in range(workers_per_domain)])

    def domains_covered(self) -> set[int]:
        return {w.domain for w in self.workers}

    def __len__(self) -> int:
        return len(self.workers)

    def __iter__(self) -> Iterator[Worker]:
        return iter(self.workers)

    def __getitem__(self, wid: int) -> Worker:
        return self.workers[wid]
