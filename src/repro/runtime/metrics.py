"""Streaming metrics for the runtime executor.

Mirrors the quantities the paper reports for its scheduling experiments —
the local/steal split of executed tasks (Fig. 3's locality story) and the
price paid for balance (here an explicit steal-penalty account, e.g.
re-prefilled tokens in the serving engine) — plus online-only signals:
per-domain queue depth over time and the high-water mark of the bounded
submission pool (backpressure verification).
"""
from __future__ import annotations

import dataclasses
from collections import deque


@dataclasses.dataclass
class RuntimeStats:
    submitted: int = 0
    executed: int = 0
    local: int = 0           # executed in the task's home domain, not stolen
    stolen: int = 0          # executed from a foreign queue
    remote_steals: int = 0   # steals that crossed a topology tier (level >= 2)
    inline_runs: int = 0     # executed by the submitter under backpressure
    idle_polls: int = 0      # dequeue attempts that found nothing eligible
    steal_penalty: float = 0.0   # accumulated nonlocal-access cost
    max_pool_depth: int = 0      # high-water mark of queued-but-unrun tasks

    @property
    def local_fraction(self) -> float:
        return self.local / max(self.executed, 1)

    @property
    def steal_fraction(self) -> float:
        return self.stolen / max(self.executed, 1)

    @property
    def remote_fraction(self) -> float:
        """Cross-tier (level >= 2) steals over executed tasks — always 0 on
        flat machines, the quantity the topology benchmark minimizes."""
        return self.remote_steals / max(self.executed, 1)


class MetricsRecorder:
    """Counters plus a bounded time series of per-domain queue depths."""

    def __init__(self, depth_window: int = 4096, depth_stride: int = 1):
        if depth_stride < 1:
            raise ValueError(f"depth_stride must be >= 1, got {depth_stride}")
        self.stats = RuntimeStats()
        self.depth_stride = depth_stride
        self._depths: deque[tuple[int, tuple[int, ...]]] = deque(maxlen=depth_window)

    # -- hooks called by the executor --------------------------------------
    def on_submit(self, pool_depth: int) -> None:
        self.stats.submitted += 1
        self.stats.max_pool_depth = max(self.stats.max_pool_depth, pool_depth)

    def on_execute(self, local: bool, stolen: bool, penalty: float,
                   inline: bool, remote: bool = False) -> None:
        self.stats.executed += 1
        if local:
            self.stats.local += 1
        if stolen:
            self.stats.stolen += 1
            self.stats.steal_penalty += penalty
            if remote:
                self.stats.remote_steals += 1
        if inline:
            self.stats.inline_runs += 1

    def on_idle(self) -> None:
        self.stats.idle_polls += 1

    def wants_depths(self, step: int) -> bool:
        """Whether ``step`` falls on the depth-sampling stride.  The
        executor consults this before building the O(domains) size list, so
        a stride > 1 skips the sampling cost, not just the storage."""
        return step % self.depth_stride == 0

    def sample_depths(self, step: int, sizes: list[int]) -> None:
        self._depths.append((step, tuple(sizes)))

    # -- read side ----------------------------------------------------------
    def depth_series(self) -> list[tuple[int, tuple[int, ...]]]:
        return list(self._depths)

    def snapshot(self) -> dict[str, float]:
        s = self.stats
        return {
            "submitted": s.submitted,
            "executed": s.executed,
            "local": s.local,
            "stolen": s.stolen,
            "remote_steals": s.remote_steals,
            "inline_runs": s.inline_runs,
            "idle_polls": s.idle_polls,
            "steal_penalty": s.steal_penalty,
            "max_pool_depth": s.max_pool_depth,
            "local_fraction": s.local_fraction,
            "steal_fraction": s.steal_fraction,
        }
