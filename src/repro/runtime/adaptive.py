"""Steal governors: when is a worker allowed to steal?

The paper's answer is "always" — load balance strictly dominates locality
(§2.2), which is right for its memory-bound stencil where a steal costs a
bounded nonlocal-bandwidth penalty.  Online workloads (the serving engine)
can have much steeper steal penalties (a full prefix re-prefill), so the
runtime makes the decision pluggable:

  ``GreedySteal``   — the paper's behaviour: any nonempty victim is fair game.
  ``NoSteal``       — never steal (models ``schedule(static)`` worksharing:
                      pure locality, no balancing).
  ``AdaptiveSteal`` — queue-depth-driven throttling (beyond the paper, toward
                      the roadmap): steal only from victims whose backlog is
                      at least a threshold θ that tracks the observed steal
                      penalty, and decay θ while a worker idles so balance
                      still wins in the limit — the paper's balance-over-
                      locality ordering is preserved, just delayed until the
                      expected payoff covers the penalty.

Governors see only queue depths and their own steal/idle history, never task
contents — they compose with any ``DomainQueues`` steal order.
"""
from __future__ import annotations

from typing import Optional

from .workers import Worker


class StealGovernor:
    """Base contract: a minimum victim depth per dequeue attempt."""

    def min_victim_depth(self, worker: Worker) -> Optional[int]:
        """Victims need at least this many queued tasks to be stolen from;
        ``None`` forbids stealing entirely for this attempt."""
        return 1

    def min_victim_depth_at(self, worker: Worker,
                            level: int) -> Optional[int]:
        """Per-topology-tier form of ``min_victim_depth`` (level 1 = the
        nearest tier).  The executor consults it only under a hierarchical
        ``repro.topology.DistanceMatrix``; the base contract prices every
        tier at the flat threshold, so level-blind governors behave
        identically on flat and hierarchical machines."""
        return self.min_victim_depth(worker)

    def on_idle(self, worker: Worker) -> None:
        """Called when ``worker`` polled and found nothing it may take."""

    def on_execute(self, worker: Worker, stolen: bool, penalty: float,
                   cost: float = 1.0, level: int = 1) -> None:
        """Called after ``worker`` executed a task.  ``cost`` is the task's
        local execution cost (its measured service time is ``cost+penalty``)
        so governors can learn service times online instead of being
        configured with static hints (``repro.trace.MeasuredPenalty``).
        ``level`` is the topology tier the task was stolen across (1 on
        flat machines, 0 for local executions) so governors can learn
        per-tier penalties."""


class GreedySteal(StealGovernor):
    """The paper's §2.2 policy: steal whenever the local queue is dry."""


class NoSteal(StealGovernor):
    """Pure locality — workers only ever serve their own domain."""

    def min_victim_depth(self, worker: Worker) -> Optional[int]:
        return None


class AdaptiveSteal(StealGovernor):
    """Depth-thresholded stealing with an online penalty estimate.

    θ = clamp(round(penalty_estimate / task_cost), 1, max_threshold): a steal
    is worthwhile when the victim's backlog is deep enough that helping out
    beats the nonlocal penalty.  Each consecutive idle poll lowers a worker's
    effective θ by one (floor 1), so a starved worker always steals
    eventually — progress is guaranteed and the throttle only reorders work.
    The penalty estimate starts at ``penalty_hint`` and follows observed
    steal penalties by an exponential moving average.

    Under a hierarchical topology the governor additionally learns one
    penalty EMA *per steal tier* (steals report their topology ``level``):
    crossing a pod costs more than crossing a socket, so each tier earns its
    own θ (``min_victim_depth_at``), seeded from the flat ``penalty_hint``
    until that tier has been observed.  The flat ``threshold`` /
    ``penalty_estimate`` pair keeps its original all-steals semantics, so
    flat-machine behaviour (every steal is level 1) is unchanged.
    """

    def __init__(self, penalty_hint: float = 4.0, task_cost: float = 1.0,
                 ema: float = 0.2, max_threshold: int = 64):
        if task_cost <= 0:
            raise ValueError("task_cost must be positive")
        if not 0.0 < ema <= 1.0:
            raise ValueError("ema must be in (0, 1]")
        self.task_cost = task_cost
        self.ema = ema
        self.max_threshold = max_threshold
        self._penalty = float(penalty_hint)
        self._level_penalty: dict[int, float] = {}
        # plain dict, read via .get: a defaultdict here would grow on every
        # read (min_victim_depth inserts a zero per probed worker) and its
        # live view leaked through accessors lets callers mutate governor
        # state — the linter's state-view rule now guards this class of bug
        self._idle: dict[int, int] = {}

    @property
    def threshold(self) -> int:
        return min(max(round(self._penalty / self.task_cost), 1),
                   self.max_threshold)

    @property
    def penalty_estimate(self) -> float:
        return self._penalty

    def threshold_at(self, level: int) -> int:
        """Per-tier θ: priced from that tier's own penalty EMA, falling back
        to the flat estimate for tiers never yet stolen across."""
        est = self._level_penalty.get(level, self._penalty)
        return min(max(round(est / self.task_cost), 1), self.max_threshold)

    def level_penalty_estimates(self) -> dict[int, float]:
        """Learned per-tier penalty EMAs (tier -> estimate); empty until a
        hierarchical run reports steal levels.  Snapshot surface for
        ``repro.spec.GovernorStateSpec``."""
        return dict(self._level_penalty)

    def seed_level_penalties(self, estimates: dict[int, float]) -> None:
        """Restore per-tier penalty EMAs from a snapshot (checkpoint/
        restore counterpart of ``level_penalty_estimates``)."""
        self._level_penalty.update(
            {int(lv): float(est) for lv, est in estimates.items()})

    def idle_counts(self) -> dict[int, int]:
        """Consecutive idle polls per worker id — a plain-dict snapshot
        (mutating it never touches the governor)."""
        return dict(self._idle)

    def min_victim_depth(self, worker: Worker) -> Optional[int]:
        return max(self.threshold - self._idle.get(worker.wid, 0), 1)

    def min_victim_depth_at(self, worker: Worker,
                            level: int) -> Optional[int]:
        return max(self.threshold_at(level) - self._idle.get(worker.wid, 0),
                   1)

    def on_idle(self, worker: Worker) -> None:
        self._idle[worker.wid] = self._idle.get(worker.wid, 0) + 1

    def on_execute(self, worker: Worker, stolen: bool, penalty: float,
                   cost: float = 1.0, level: int = 1) -> None:
        self._idle[worker.wid] = 0
        if stolen:
            self._penalty = (1 - self.ema) * self._penalty + self.ema * penalty
            prev = self._level_penalty.get(level)
            self._level_penalty[level] = (
                penalty if prev is None
                else (1 - self.ema) * prev + self.ema * penalty)
