"""Streaming event trace for the runtime.

Every scheduling decision emits one ``Event``; the ``EventLog`` is a bounded
ring buffer so long-running (online) executors can keep tracing without
growing memory.  Events are the raw material for the metrics layer and for
offline debugging of steal behaviour — the online analogue of the
per-thread timelines behind the paper's Fig. 4 variability analysis.

Event kinds:
  ``submit``  — a task entered a domain queue
  ``run``     — a worker executed a task from its own domain's queue
  ``steal``   — a worker executed a task taken from a foreign queue
  ``inline``  — the submitter executed a task because the pool was full
                (OpenMP §2.1 backpressure)
  ``idle``    — a worker polled for work and found none it may take
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Iterator

KINDS = ("submit", "run", "steal", "inline", "idle")


@dataclasses.dataclass(frozen=True)
class Event:
    step: int          # executor scheduling round (0 for submissions)
    kind: str
    worker: int        # worker id, -1 for submit events
    domain: int        # queue domain acted on
    task_uid: int      # -1 for idle polls
    src_domain: int = -1   # for steals: the victim queue


class EventLog:
    """Bounded ring buffer of events (oldest dropped first)."""

    def __init__(self, maxlen: int = 65536):
        self._buf: deque[Event] = deque(maxlen=maxlen)
        self._counts: Counter[str] = Counter()

    def emit(self, step: int, kind: str, worker: int, domain: int,
             task_uid: int, src_domain: int = -1) -> None:
        self._buf.append(Event(step, kind, worker, domain, task_uid, src_domain))
        self._counts[kind] += 1

    def counts(self) -> dict[str, int]:
        """Totals per kind over the whole run (not just the retained window)."""
        return dict(self._counts)

    def tail(self, n: int = 50) -> list[Event]:
        return list(self._buf)[-n:]

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[Event]:
        return iter(self._buf)

    def to_csv_lines(self) -> list[str]:
        out = ["step,kind,worker,domain,task_uid,src_domain"]
        out += [f"{e.step},{e.kind},{e.worker},{e.domain},{e.task_uid},"
                f"{e.src_domain}" for e in self._buf]
        return out
