"""Streaming event trace for the runtime.

Every scheduling decision emits one ``Event``; the ``EventLog`` is a bounded
ring buffer so long-running (online) executors can keep tracing without
growing memory.  Events are the raw material for the metrics layer and for
offline debugging of steal behaviour — the online analogue of the
per-thread timelines behind the paper's Fig. 4 variability analysis.

Event kinds:
  ``submit``  — a task entered a domain queue
  ``run``     — a worker executed a task from its own domain's queue
  ``steal``   — a worker executed a task taken from a foreign queue
  ``inline``  — the submitter executed a task because the pool was full
                (OpenMP §2.1 backpressure)
  ``idle``    — a worker polled for work and found none it may take

Window vs totals: the ring buffer retains only the newest ``maxlen`` events,
but ``counts()`` (and ``total``) keep counting every event ever emitted.  Any
export of the buffer therefore covers a *window* of the run, not the run —
``to_csv_lines()`` says so explicitly in a leading marker line.

Storage is columnar (struct-of-arrays): one preallocated ring per ``Event``
field, written in place by ``emit`` — appending an event is eight scalar
stores, not a frozen-dataclass construction plus a deque append plus a
Counter update (``BENCH_overhead.json`` event_append).  The per-field rings
are plain Python lists, not numpy arrays: a scalar store into a numpy array
pays dtype coercion (~5x a list store — measured, and ``emit`` is nothing
*but* scalar stores); numpy enters only at the bulk boundary, via
``columns()``, which exports the retained window as one typed numpy array
per field for vectorized analytics.  ``Event`` objects are materialized
lazily, only when the log is iterated / exported; readers see the exact
same frozen dataclass as before.  ``ReferenceEventLog`` keeps the original
object-per-event implementation as the executable specification the
columnar ring is equivalence-tested against
(``benchmarks.scheduler_overhead`` fast_vs_slow).
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import Counter, deque
from typing import Iterator

import numpy as np

KINDS = ("submit", "run", "steal", "inline", "idle")

_OVERFLOW_MSG = (
    "EventLog overflow: ring buffer (maxlen={maxlen}) is "
    "dropping oldest events; exports now cover a window of the "
    "run, not the run (counts()/total remain whole-run)")


@dataclasses.dataclass(frozen=True)
class Event:
    step: int          # executor scheduling round (0 for submissions)
    kind: str
    worker: int        # worker id, -1 for submit events
    domain: int        # queue domain acted on
    task_uid: int      # -1 for idle polls
    src_domain: int = -1   # for steals: the victim queue
    cost: float = 0.0      # task's local execution cost (run/steal/inline)
    penalty: float = 0.0   # nonlocal penalty actually charged (steal only)

    @property
    def service(self) -> float:
        """Measured service time of an execution event: the local cost plus
        any nonlocal penalty paid.  0.0 for submit/idle events."""
        return self.cost + self.penalty


def _check_maxlen(maxlen) -> int:
    if maxlen is None or maxlen < 1:
        raise ValueError(f"EventLog maxlen must be >= 1, got {maxlen!r} "
                         "(a degenerate ring would drop every event)")
    return int(maxlen)


class EventLog:
    """Bounded ring buffer of events (oldest dropped first), stored as one
    column per field.

    ``emit``'s one-shot overflow warning is raised at ``stacklevel=2`` —
    it points at ``emit``'s direct caller (``Executor._emit`` for
    executor-driven logs, the call site itself for direct use).
    """

    def __init__(self, maxlen: int = 65536):
        maxlen = _check_maxlen(maxlen)
        self.maxlen = maxlen
        self._step = [0] * maxlen
        self._kind = [0] * maxlen          # index into the kind registry
        self._worker = [0] * maxlen
        self._domain = [0] * maxlen
        self._uid = [0] * maxlen
        self._src = [0] * maxlen
        self._cost = [0.0] * maxlen
        self._penalty = [0.0] * maxlen
        self._n = 0                        # events ever emitted
        # per-instance kind registry: the canonical KINDS up front, unknown
        # kinds appended on first use (the old Counter accepted any string)
        self._kinds: list[str] = list(KINDS)
        self._kind_id: dict[str, int] = {k: i for i, k in enumerate(KINDS)}
        self._kind_counts: list[int] = [0] * len(KINDS)
        self._warned_overflow = False

    def emit(self, step: int, kind: str, worker: int, domain: int,
             task_uid: int, src_domain: int = -1, cost: float = 0.0,
             penalty: float = 0.0) -> None:
        n = self._n
        maxlen = self.maxlen
        if n >= maxlen and not self._warned_overflow:
            # One-shot: overflow used to be silent, and window-sensitive
            # analyses (storm detection, span assembly) quietly degraded.
            # counts()/total stay whole-run; only the retained window drops.
            self._warned_overflow = True
            warnings.warn(_OVERFLOW_MSG.format(maxlen=maxlen),
                          RuntimeWarning, stacklevel=2)
        try:
            k = self._kind_id[kind]
        except KeyError:
            k = self._register_kind(kind)
        i = n % maxlen
        self._step[i] = step
        self._kind[i] = k
        self._worker[i] = worker
        self._domain[i] = domain
        self._uid[i] = task_uid
        self._src[i] = src_domain
        self._cost[i] = cost
        self._penalty[i] = penalty
        self._n = n + 1
        self._kind_counts[k] += 1

    def _register_kind(self, kind: str) -> int:
        if len(self._kinds) >= 256:   # uint8 kind column in columns()
            raise ValueError("EventLog supports at most 256 distinct kinds")
        k = len(self._kinds)
        self._kinds.append(kind)
        self._kind_id[kind] = k
        self._kind_counts.append(0)
        return k

    def counts(self) -> dict[str, int]:
        """Totals per kind over the whole run (not just the retained window)."""
        return {k: c for k, c in zip(self._kinds, self._kind_counts) if c}

    @property
    def total(self) -> int:
        """Events emitted over the whole run (retained + dropped)."""
        return self._n

    @property
    def dropped(self) -> int:
        """Events the ring buffer has already discarded (oldest first)."""
        return max(self._n - self.maxlen, 0)

    def _window(self, lo: int, hi: int) -> list[int]:
        """Ring indices for absolute emit indices ``[lo, hi)``, unwrapped."""
        maxlen = self.maxlen
        lo_i, hi_i = lo % maxlen, ((hi - 1) % maxlen) + 1
        if lo_i < hi_i:
            return list(range(lo_i, hi_i))
        return list(range(lo_i, maxlen)) + list(range(hi_i))

    def _materialize(self, lo: int, hi: int) -> list[Event]:
        """Decode absolute emit indices ``[lo, hi)`` into ``Event`` objects.

        One gather per column over the unwrapped ring window, then a
        plain-tuple zip into the dataclass — every field is already a
        native Python int/float (JSON-safe).
        """
        if lo >= hi:
            return []
        idx = self._window(lo, hi)
        kinds = self._kinds
        return [Event(s, kinds[k], w, d, u, sd, c, p)
                for s, k, w, d, u, sd, c, p in zip(
                    [self._step[i] for i in idx],
                    [self._kind[i] for i in idx],
                    [self._worker[i] for i in idx],
                    [self._domain[i] for i in idx],
                    [self._uid[i] for i in idx],
                    [self._src[i] for i in idx],
                    [self._cost[i] for i in idx],
                    [self._penalty[i] for i in idx])]

    def columns(self) -> dict[str, np.ndarray]:
        """The retained window as one typed numpy array per field, oldest
        first — the bulk boundary where columnar storage pays off: trace
        export and vectorized analytics read whole columns, never an
        ``Event`` object per row.  ``kind`` comes out as ``uint8`` indices
        into ``kind_names()``."""
        lo = self._n - len(self)
        idx = self._window(lo, self._n) if self._n else []
        dtypes = {"step": np.int64, "kind": np.uint8, "worker": np.int32,
                  "domain": np.int32, "task_uid": np.int64,
                  "src_domain": np.int32, "cost": np.float64,
                  "penalty": np.float64}
        cols = {"step": self._step, "kind": self._kind,
                "worker": self._worker, "domain": self._domain,
                "task_uid": self._uid, "src_domain": self._src,
                "cost": self._cost, "penalty": self._penalty}
        return {name: np.array([col[i] for i in idx], dtype=dtypes[name])
                for name, col in cols.items()}

    def kind_names(self) -> tuple[str, ...]:
        """Registry decoding ``columns()['kind']`` indices to kind strings."""
        return tuple(self._kinds)

    def tail(self, n: int = 50) -> list[Event]:
        lo = max(self._n - min(n, len(self)), 0)
        return self._materialize(lo, self._n)

    def __len__(self) -> int:
        return min(self._n, self.maxlen)

    def __iter__(self) -> Iterator[Event]:
        return iter(self._materialize(self._n - len(self), self._n))

    def to_csv_lines(self) -> list[str]:
        """CSV export of the *retained window* only.

        The first line is a ``#`` marker recording total vs retained vs
        dropped so a truncated export can never be mistaken for the whole
        run (``counts()`` always covers the whole run).
        """
        out = [f"# events total={self.total} retained={len(self)} "
               f"dropped={self.dropped} window={self.maxlen}",
               "step,kind,worker,domain,task_uid,src_domain,cost,penalty"]
        out += [f"{e.step},{e.kind},{e.worker},{e.domain},{e.task_uid},"
                f"{e.src_domain},{e.cost:g},{e.penalty:g}" for e in self]
        return out


class ReferenceEventLog:
    """The pre-columnar object-per-event ring: one frozen ``Event`` built
    per emit into a ``deque``.  Kept as the executable specification —
    ``benchmarks.scheduler_overhead``'s fast_vs_slow block and the runtime
    tests hold ``EventLog`` to producing the identical event sequence,
    counts, and CSV export."""

    def __init__(self, maxlen: int = 65536):
        maxlen = _check_maxlen(maxlen)
        self.maxlen = maxlen
        self._buf: deque[Event] = deque(maxlen=maxlen)
        self._counts: Counter[str] = Counter()
        self._warned_overflow = False

    def emit(self, step: int, kind: str, worker: int, domain: int,
             task_uid: int, src_domain: int = -1, cost: float = 0.0,
             penalty: float = 0.0) -> None:
        if not self._warned_overflow and len(self._buf) == self.maxlen:
            self._warned_overflow = True
            warnings.warn(_OVERFLOW_MSG.format(maxlen=self.maxlen),
                          RuntimeWarning, stacklevel=2)
        self._buf.append(Event(step, kind, worker, domain, task_uid,
                               src_domain, cost, penalty))
        self._counts[kind] += 1

    def counts(self) -> dict[str, int]:
        return dict(self._counts)

    @property
    def total(self) -> int:
        return sum(self._counts.values())

    @property
    def dropped(self) -> int:
        return self.total - len(self._buf)

    def tail(self, n: int = 50) -> list[Event]:
        return list(self._buf)[-n:]

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[Event]:
        return iter(self._buf)

    def to_csv_lines(self) -> list[str]:
        out = [f"# events total={self.total} retained={len(self._buf)} "
               f"dropped={self.dropped} window={self.maxlen}",
               "step,kind,worker,domain,task_uid,src_domain,cost,penalty"]
        out += [f"{e.step},{e.kind},{e.worker},{e.domain},{e.task_uid},"
                f"{e.src_domain},{e.cost:g},{e.penalty:g}" for e in self._buf]
        return out
