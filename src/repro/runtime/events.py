"""Streaming event trace for the runtime.

Every scheduling decision emits one ``Event``; the ``EventLog`` is a bounded
ring buffer so long-running (online) executors can keep tracing without
growing memory.  Events are the raw material for the metrics layer and for
offline debugging of steal behaviour — the online analogue of the
per-thread timelines behind the paper's Fig. 4 variability analysis.

Event kinds:
  ``submit``  — a task entered a domain queue
  ``run``     — a worker executed a task from its own domain's queue
  ``steal``   — a worker executed a task taken from a foreign queue
  ``inline``  — the submitter executed a task because the pool was full
                (OpenMP §2.1 backpressure)
  ``idle``    — a worker polled for work and found none it may take

Window vs totals: the ring buffer retains only the newest ``maxlen`` events,
but ``counts()`` (and ``total``) keep counting every event ever emitted.  Any
export of the buffer therefore covers a *window* of the run, not the run —
``to_csv_lines()`` says so explicitly in a leading marker line.
"""
from __future__ import annotations

import dataclasses
import warnings
from collections import Counter, deque
from typing import Iterator

KINDS = ("submit", "run", "steal", "inline", "idle")


@dataclasses.dataclass(frozen=True)
class Event:
    step: int          # executor scheduling round (0 for submissions)
    kind: str
    worker: int        # worker id, -1 for submit events
    domain: int        # queue domain acted on
    task_uid: int      # -1 for idle polls
    src_domain: int = -1   # for steals: the victim queue
    cost: float = 0.0      # task's local execution cost (run/steal/inline)
    penalty: float = 0.0   # nonlocal penalty actually charged (steal only)

    @property
    def service(self) -> float:
        """Measured service time of an execution event: the local cost plus
        any nonlocal penalty paid.  0.0 for submit/idle events."""
        return self.cost + self.penalty


class EventLog:
    """Bounded ring buffer of events (oldest dropped first)."""

    def __init__(self, maxlen: int = 65536):
        self.maxlen = maxlen
        self._buf: deque[Event] = deque(maxlen=maxlen)
        self._counts: Counter[str] = Counter()
        self._warned_overflow = False

    def emit(self, step: int, kind: str, worker: int, domain: int,
             task_uid: int, src_domain: int = -1, cost: float = 0.0,
             penalty: float = 0.0) -> None:
        if not self._warned_overflow and len(self._buf) == self.maxlen:
            # One-shot: overflow used to be silent, and window-sensitive
            # analyses (storm detection, span assembly) quietly degraded.
            # counts()/total stay whole-run; only the retained window drops.
            self._warned_overflow = True
            warnings.warn(
                f"EventLog overflow: ring buffer (maxlen={self.maxlen}) is "
                "dropping oldest events; exports now cover a window of the "
                "run, not the run (counts()/total remain whole-run)",
                RuntimeWarning, stacklevel=3)
        self._buf.append(Event(step, kind, worker, domain, task_uid,
                               src_domain, cost, penalty))
        self._counts[kind] += 1

    def counts(self) -> dict[str, int]:
        """Totals per kind over the whole run (not just the retained window)."""
        return dict(self._counts)

    @property
    def total(self) -> int:
        """Events emitted over the whole run (retained + dropped)."""
        return sum(self._counts.values())

    @property
    def dropped(self) -> int:
        """Events the ring buffer has already discarded (oldest first)."""
        return self.total - len(self._buf)

    def tail(self, n: int = 50) -> list[Event]:
        return list(self._buf)[-n:]

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[Event]:
        return iter(self._buf)

    def to_csv_lines(self) -> list[str]:
        """CSV export of the *retained window* only.

        The first line is a ``#`` marker recording total vs retained vs
        dropped so a truncated export can never be mistaken for the whole
        run (``counts()`` always covers the whole run).
        """
        out = [f"# events total={self.total} retained={len(self._buf)} "
               f"dropped={self.dropped} window={self.maxlen}",
               "step,kind,worker,domain,task_uid,src_domain,cost,penalty"]
        out += [f"{e.step},{e.kind},{e.worker},{e.domain},{e.task_uid},"
                f"{e.src_domain},{e.cost:g},{e.penalty:g}" for e in self._buf]
        return out
