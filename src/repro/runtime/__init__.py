"""repro.runtime — the online locality-aware task runtime.

This package is the paper's contribution lifted out of the offline
discrete-event simulator and made *online*: tasks arrive dynamically, are
sorted into per-locality-domain FIFO queues, and domain-pinned workers
serve them local-first with balance-over-locality stealing.  It is the
single home of the steal-scan logic — both the simulator policies
(`repro.core.scheduler`) and the serving router (`repro.serving.engine`)
are thin drivers over these primitives.

Paper-concept map (Wittmann & Hager, 2010):

  paper concept (§)                      runtime object
  -------------------------------------  ---------------------------------
  locality domain, ``ld_ID`` map (§1.3)  domain index; ``WorkerPool`` pinning
  one task = one block (§2.1)            ``Task`` (``home`` = page placement)
  bounded task pool, ~256 (§2.1)         ``Executor(pool_cap=...)`` +
                                         ``SubmissionPool``; full pool makes
                                         the submitter run tasks inline
  locality queues + steal scan (§2.2)    ``DomainQueues`` (``cyclic`` order)
  TBB random stealing (§3.1)             ``DomainQueues`` (``random`` order)
  nonlocal-access penalty (§1.4)         ``steal_penalty`` callback, summed
                                         in ``RuntimeStats.steal_penalty``
  balance over locality (§2.2)           ``GreedySteal`` governor; the
                                         ``AdaptiveSteal`` governor throttles
                                         it by queue depth (beyond the paper)

The table continues in ``repro/trace/__init__.py`` — workload generation,
trace export, deterministic replay, and steal-storm analysis over these
primitives (record a run via ``Executor(submit_hook=...)``) — and in
``repro/control/__init__.py`` — the online control plane that adjusts
routing, batch size, and the steal threshold through the executor's
``router``/``batch``/``governor``/``step_hook`` knobs.

Usage::

    from repro.runtime import AdaptiveSteal, Executor

    ex = Executor(num_domains=4, steal_order="cyclic",
                  handler=lambda task, worker: work(task.payload, worker),
                  steal_penalty=lambda task, worker: task.cost,
                  governor=AdaptiveSteal(penalty_hint=4.0))
    for item, home in arrivals:                 # online submission
        ex.submit(ex.make_task(item, home=home))
        ex.step()                               # overlap arrival + service
    results = ex.run_until_drained()
    print(ex.stats.local_fraction, ex.stats.steal_fraction,
          ex.stats.steal_penalty)
"""
from .adaptive import AdaptiveSteal, GreedySteal, NoSteal, StealGovernor
from .events import Event, EventLog, ReferenceEventLog
from .executor import Executor, Task
from .metrics import MetricsRecorder, RuntimeStats
from .queues import DomainQueues, Popped, SubmissionPool
from .workers import Worker, WorkerPool, WorkerStats

__all__ = [
    "AdaptiveSteal", "GreedySteal", "NoSteal", "StealGovernor",
    "Event", "EventLog", "ReferenceEventLog",
    "Executor", "Task",
    "MetricsRecorder", "RuntimeStats",
    "DomainQueues", "Popped", "SubmissionPool",
    "Worker", "WorkerPool", "WorkerStats",
]
