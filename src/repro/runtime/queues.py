"""Canonical queue primitives of the locality-queue runtime.

``DomainQueues`` is the single implementation of the paper's §2.2 data
structure in this repo: one FIFO queue per locality domain, local-first
dequeue, and a steal scan over foreign queues when the local queue is dry
(balance deliberately wins over locality).  Both the offline simulator
policies (`repro.core.scheduler`) and the online serving router
(`repro.serving.engine`) route through this class — there is no second
copy of the steal-scan logic anywhere.

Four steal scans are supported:

  ``cyclic``        — the paper's scan: victims visited in domain order
                      starting right after the caller's own domain (§2.2).
  ``longest``       — steal from the deepest foreign queue (the serving
                      router's balance-first variant; ties break on lowest
                      domain id).
  ``random``        — uniform random victim among eligible queues (models
                      TBB's random stealing, §3.1); requires an ``rng``.
  ``cost_weighted`` — steal from the foreign queue holding the most queued
                      *cost* (sum of item ``cost`` attributes, 1.0 when
                      absent; ties break on lowest domain id).  With
                      heavy-tailed service costs a short queue can hide the
                      biggest backlog; this scan relieves the domain with
                      the most queued *work*, not the most queued *items*
                      (the ``repro.control`` cost-aware victim selection).

With a hierarchical ``repro.topology.DistanceMatrix`` attached, every scan
becomes *nearest-first*: victims are sought level by level (same socket,
then cross socket, then cross pod) and the configured order applies only
*within* a level — the paper's dynamic-scheduling-inside-a-domain invariant
is preserved per tier, while a worker never pays a deep-link steal when a
sibling still has eligible work.  ``min_victim`` may then be a per-level
sequence (the adaptive governor's per-level θ; a ``None`` entry forbids
that tier outright).  A flat (or absent) topology takes the original
single-tier code path untouched, RNG draws and all — flat runs are
bit-identical to the pre-topology runtime.

Queued cost is tracked per domain (``cost`` / ``queue_costs``), so
cost-aware routing and victim selection are O(domains) reads, never a
queue walk.  The cost of each item is **snapshotted at enqueue** right
next to the item (each queue slot is an ``(item, cost)`` pair) and that
same snapshot is subtracted at dequeue — mutating a task's ``cost``
attribute while it sits queued (e.g. measured-penalty repricing) can
therefore never drift the account.  An emptied queue's
cost returns to exactly 0.0 whenever the snapshot arithmetic is exact
(integral / dyadic costs — every committed workload); adversarial float
costs can leave a ±ulp residue, which is the accounting being honest, not
drifting.

Victim selection has two implementations, selected by the ``fast`` flag:

  ``fast=True``  (default) — incrementally-maintained eligibility
      structures: a nonempty-domain bitmask (empty↔nonempty transitions
      are one ``|=``/``&=``; the cyclic successor is two's-complement bit
      arithmetic — O(d/64) word ops in C, no Python loop), lazy max-heaps
      keyed on depth / queued cost (``longest`` / ``cost_weighted``
      selection is amortized O(log d)), and per-level nonempty-peer
      counters that let the hierarchical scan skip whole tiers in O(1).
  ``fast=False`` — the pre-rewrite O(domains) linear scans, kept verbatim
      as the executable specification.

The two paths are **bit-identical**: same victim, same visit order, and
the same RNG draw sequence (``random`` draws once over the identical
ascending eligible list, and draws nothing when no victim is eligible).
``benchmarks.scheduler_overhead``'s ``fast_vs_slow`` block and the
hypothesis property in ``tests/test_runtime.py`` hold the paths to that
contract.

``SubmissionPool`` captures the other half of the paper's machinery: the
bounded FIFO pool of submitted-but-unconsumed tasks of OpenMP tasking
(§2.1, "the limit is set to roughly 256 tasks").  The cap is advisory —
callers consult ``full``/``free_slots`` and apply backpressure themselves
(the simulator has its submitter run a task when full; the online
``Executor`` does the same inline).
"""
from __future__ import annotations

from collections import deque
from heapq import heapify, heappop, heappush
from typing import Any, NamedTuple, Optional, Sequence, Union

import numpy as np

MinVictim = Union[int, Sequence[Optional[int]]]


class Popped(NamedTuple):
    """Result of a ``DomainQueues.dequeue``.

    ``level``/``distance`` locate the steal in the topology: 0/0.0 for a
    local pop, the victim's tier and link cost for a steal (1/1.0 when no
    topology is attached — the flat machine's uniform hop).

    A ``NamedTuple`` rather than a frozen dataclass: one ``Popped`` is
    built per executed task, and tuple construction keeps that off the
    scheduler's critical path (``BENCH_overhead.json`` steal_scan).
    """

    item: Any
    domain: int        # queue the item came from
    stolen: bool       # True when it came from a foreign queue
    level: int = 0     # topology tier of the steal (0 = local)
    distance: float = 0.0   # link cost scale of the steal (0.0 = local)


# the local-pop hot path builds Popped through C-level tuple.__new__;
# the generated namedtuple __new__ is a Python frame per executed task
_tuple_new = tuple.__new__


class DomainQueues:
    """Per-domain FIFO queues with a local-first dequeue and a steal scan."""

    STEAL_ORDERS = ("cyclic", "longest", "random", "cost_weighted")

    def __init__(self, num_domains: int, steal_order: str = "cyclic",
                 rng: np.random.Generator | None = None,
                 topology=None, fast: bool = True):
        if num_domains < 1:
            raise ValueError("need at least one domain")
        if steal_order not in self.STEAL_ORDERS:
            raise ValueError(f"unknown steal order {steal_order!r} "
                             f"(want one of {self.STEAL_ORDERS})")
        if steal_order == "random" and rng is None:
            raise ValueError("steal_order='random' needs an rng")
        if topology is not None and topology.num_domains != num_domains:
            raise ValueError(
                f"topology covers {topology.num_domains} domains, "
                f"queues have {num_domains}")
        self.num_domains = num_domains
        self.steal_order = steal_order
        self.topology = topology
        self.fast = fast
        self._rng = rng
        # each slot is an (item, cost) pair: the enqueue-time cost snapshot
        # travels with the item (the drift fix), and the fused layout costs
        # one popleft per pop instead of two on the scheduler's hot path
        self._queues: list[deque[tuple[Any, float]]] = [
            deque() for _ in range(num_domains)]
        self._costs: list[float] = [0.0] * num_domains
        self._size = 0
        # -- fast-path eligibility structures ------------------------------
        # (queue depth itself is never duplicated: ``len(deque)`` is O(1),
        # so a shadow depth array would be pure per-pop maintenance cost)
        self._hier = (fast and topology is not None and topology.hierarchical)
        self._ne_mask = 0                # bit d set <=> domain d nonempty
        # lazy max-heap of (-depth, d) / (-cost, d); entries go stale when
        # the domain's state moves on and are discarded at query time
        self._order_heap: Optional[list[tuple[float, int]]] = None
        if fast and not self._hier and steal_order in ("longest",
                                                       "cost_weighted"):
            self._order_heap = []
        self._heap_limit = max(64, 8 * num_domains)
        # per-level nonempty-peer counters: _lvl_nonempty[a][lv-1] counts
        # nonempty domains at tier lv from a's viewpoint, so the nearest-
        # first scan can skip a whole tier in O(1)
        self._lvl_nonempty: Optional[list[list[int]]] = None
        if self._hier:
            self._lvl_nonempty = [[0] * topology.num_levels
                                  for _ in range(num_domains)]

    @staticmethod
    def _item_cost(item: Any) -> float:
        return float(getattr(item, "cost", 1.0))

    # -- fast-path maintenance ---------------------------------------------
    def _heap_push(self, key: float, domain: int) -> None:
        heap = self._order_heap
        if len(heap) >= self._heap_limit:
            # compaction: rebuild from current state so the heap stays
            # O(domains) even on steal-free runs that never drain it
            self._rebuild_heap()
            heap = self._order_heap
        heappush(heap, (key, domain))

    def _rebuild_heap(self) -> None:
        if self.steal_order == "longest":
            heap = [(-len(self._queues[d]), d) for d in self._mask_domains()]
        else:
            heap = [(-self._costs[d], d) for d in self._mask_domains()]
        heapify(heap)
        self._order_heap = heap

    def _mask_domains(self) -> list[int]:
        """The nonempty-domain bitmask decoded to ascending domain ids."""
        m = self._ne_mask
        out = []
        while m:
            b = m & -m                   # lowest set bit
            out.append(b.bit_length() - 1)
            m ^= b
        return out

    def _lvl_shift(self, domain: int, delta: int) -> None:
        """Shift every peer's nonempty-at-tier counter when ``domain``
        crosses the empty↔nonempty boundary (hierarchical fast path)."""
        lvl = self._lvl_nonempty
        topo = self.topology
        for a in range(self.num_domains):
            if a != domain:
                lvl[a][topo.level(a, domain) - 1] += delta

    # -- producer side -----------------------------------------------------
    def enqueue(self, item: Any, domain: int) -> None:
        cost = float(getattr(item, "cost", 1.0))   # snapshot at enqueue
        q = self._queues[domain]
        q.append((item, cost))
        self._costs[domain] += cost
        self._size += 1
        if self.fast:
            if len(q) == 1:
                self._ne_mask |= 1 << domain
                if self._lvl_nonempty is not None:
                    self._lvl_shift(domain, 1)
            if self._order_heap is not None:
                if self.steal_order == "longest":
                    self._heap_push(-len(q), domain)
                else:
                    self._heap_push(-self._costs[domain], domain)

    # -- consumer side -----------------------------------------------------
    def dequeue(self, domain: int, allow_steal: bool = True,
                min_victim: MinVictim = 1) -> Optional[Popped]:
        """Pop the oldest local item; steal from a foreign queue otherwise.

        ``min_victim`` throttles stealing: only victims holding at least
        that many items are eligible (1 = the paper's greedy behaviour;
        larger values are the adaptive governor's depth threshold).  With a
        hierarchical topology it may be a per-level sequence — entry
        ``level-1`` gates that tier, ``None`` forbids it (the breaker's
        remote cut); a short sequence extends with its last entry.
        """
        q = self._queues[domain]
        if q:
            # local pop, ``_pop`` inlined: the single hottest line in the
            # scheduler (BENCH_overhead.json steal_scan) — one call frame
            # per executed task is worth the duplication
            item, cost = q.popleft()
            self._costs[domain] -= cost
            self._size -= 1
            if self.fast:
                if not q:
                    self._ne_mask &= ~(1 << domain)
                    if self._lvl_nonempty is not None:
                        self._lvl_shift(domain, -1)
                elif self._order_heap is not None:
                    if self.steal_order == "longest":
                        self._heap_push(-len(q), domain)
                    else:
                        self._heap_push(-self._costs[domain], domain)
            # C-level tuple.__new__: the namedtuple's keyword/default
            # __new__ costs ~200ns more per executed task
            return _tuple_new(Popped, (item, domain, False, 0, 0.0))
        if not allow_steal:
            return None
        if self.fast and not self._ne_mask:
            return None     # machine-wide empty: no victim anywhere
        victim = self._pick_victim(domain, min_victim)
        if victim is None:
            return None
        topo = self.topology
        if topo is None:
            level, dist = 1, 1.0
        else:
            level, dist = topo.level(domain, victim), topo.distance(domain,
                                                                    victim)
        return Popped(self._pop(victim), victim, True, level, dist)

    def _pop(self, domain: int) -> Any:
        q = self._queues[domain]
        # subtract the enqueue-time snapshot, never the item's live cost: a
        # queued task whose ``cost`` mutated in the meantime must not drift
        # the account (the old live-cost subtraction needed a re-zero-on-
        # empty mask to hide exactly that drift; both are gone)
        item, cost = q.popleft()
        self._costs[domain] -= cost
        self._size -= 1
        if self.fast:
            if not q:
                self._ne_mask &= ~(1 << domain)
                if self._lvl_nonempty is not None:
                    self._lvl_shift(domain, -1)
            elif self._order_heap is not None:
                if self.steal_order == "longest":
                    self._heap_push(-len(q), domain)
                else:
                    self._heap_push(-self._costs[domain], domain)
        return item

    def drain(self, domain: int, n: int, budget: Optional[float] = None,
              spent: float = 0.0) -> list[Any]:
        """Pop up to ``n`` more items from ``domain``'s queue, FIFO, no steal
        scan — the executor's batch-grab primitive: after a dequeue picked a
        source queue, the rest of the batch is taken from the *same* queue so
        a batch never mixes locality domains.

        ``budget`` bounds the grab by *cost*, not just count: draining stops
        before an item that would push ``spent`` (cost already in the batch)
        past the budget.  The cost consulted is the enqueue-time snapshot —
        the same number the queue's cost account carries — so a drain's
        budget arithmetic always matches ``cost()``/``queue_costs()``.  That
        is the token-budget form of continuous batching — a grab of cheap
        items runs wide, one expensive item fills the whole budget alone —
        and is what makes a queue's total cost an honest estimate of its
        drain *time*.
        """
        out = []
        q = self._queues[domain]
        while n > 0 and q:
            if budget is not None:
                nxt = q[0][1]     # the head item's enqueue-time snapshot
                if spent + nxt > budget:
                    break
                spent += nxt
            out.append(self._pop(domain))
            n -= 1
        return out

    @staticmethod
    def _level_min(min_victim: MinVictim, level: int) -> Optional[int]:
        """The depth threshold gating ``level`` (1-based): scalar thresholds
        apply to every tier; sequences index ``level - 1`` and extend with
        their last entry.  ``None`` forbids the tier."""
        if min_victim is None or isinstance(min_victim, int):
            return min_victim
        if not len(min_victim):
            return None
        return min_victim[min(level - 1, len(min_victim) - 1)]

    def _pick_victim(self, domain: int, min_victim: MinVictim) -> Optional[int]:
        topo = self.topology
        if topo is not None and topo.hierarchical:
            return self._pick_victim_nearest(domain, min_victim, topo)
        mv = self._level_min(min_victim, 1)
        if mv is None:
            return None
        mv = max(mv, 1)
        if self.fast:
            return self._pick_victim_flat_fast(domain, mv)
        return self._pick_victim_flat_reference(domain, mv)

    # -- reference (pre-rewrite) scans --------------------------------------
    def _pick_victim_flat_reference(self, domain: int,
                                    mv: int) -> Optional[int]:
        """The original single-tier O(domains) scan, kept verbatim: the
        executable specification the fast path is equivalence-gated
        against — same visit order and the same RNG draw sequence."""
        if self.steal_order == "cyclic":
            for off in range(1, self.num_domains):
                d = (domain + off) % self.num_domains
                if len(self._queues[d]) >= mv:
                    return d
            return None
        eligible = [d for d in range(self.num_domains)
                    if d != domain and len(self._queues[d]) >= mv]
        if not eligible:
            return None
        return self._pick_eligible(eligible)

    # -- fast flat scans ----------------------------------------------------
    def _pick_victim_flat_fast(self, domain: int, mv: int) -> Optional[int]:
        m = self._ne_mask
        if not m:
            return None
        order = self.steal_order
        if order == "cyclic":
            # first set bit after the caller's, wrapping — exactly the
            # first hit of the reference (domain+1 .. domain-1) visit
            # order, found by two's-complement bit tricks instead of a
            # Python loop (``x & -x`` isolates the lowest set bit)
            m &= ~(1 << domain)          # never self-steal
            higher = m >> (domain + 1)
            if mv == 1:
                if higher:
                    return domain + 1 + (higher & -higher).bit_length() - 1
                if m:
                    return (m & -m).bit_length() - 1
                return None
            qs = self._queues
            base = domain + 1
            while higher:
                b = higher & -higher
                d = base + b.bit_length() - 1
                if len(qs[d]) >= mv:
                    return d
                higher ^= b
            lower = m & ((1 << domain) - 1)
            while lower:
                b = lower & -lower
                d = b.bit_length() - 1
                if len(qs[d]) >= mv:
                    return d
                lower ^= b
            return None
        if order == "random":
            # identical ascending eligible list -> identical single draw
            # (and no draw at all when nothing is eligible)
            qs = self._queues
            if mv == 1:
                eligible = [d for d in self._mask_domains() if d != domain]
            else:
                eligible = [d for d in self._mask_domains()
                            if d != domain and len(qs[d]) >= mv]
            if not eligible:
                return None
            return int(eligible[int(self._rng.integers(len(eligible)))])
        if order == "longest":
            return self._pick_deepest(domain, mv)
        return self._pick_costliest(domain, mv)

    def _pick_deepest(self, domain: int, mv: int) -> Optional[int]:
        """Lazy-heap form of ``max(eligible, key=(depth, -d))``: every depth
        change pushed ``(-depth, d)``, so the shallowest key whose entry
        still matches the live depth is the true maximum (heap order breaks
        depth ties on lowest domain id, same as the reference)."""
        heap = self._order_heap
        qs = self._queues
        shelved: list[tuple[float, int]] = []
        found: Optional[int] = None
        while heap:
            negd, d = heap[0]
            if len(qs[d]) == -negd:
                if d != domain:
                    # top valid foreign entry is the true max depth; if even
                    # it misses the gate, nothing is eligible
                    found = d if -negd >= mv else None
                    break
                shelved.append(heappop(heap))  # caller's own, still valid
            else:
                heappop(heap)   # stale: discard
        for entry in shelved:
            heappush(heap, entry)
        return found

    def _pick_costliest(self, domain: int, mv: int) -> Optional[int]:
        """Lazy-heap form of ``max(eligible, key=(cost, -d))``.  Unlike
        depth, the deepest-cost domain may still fail the ``mv`` depth gate
        while a cheaper one passes, so valid-but-shallow entries are set
        aside and re-pushed after the search."""
        heap = self._order_heap
        qs = self._queues
        costs = self._costs
        shelved: list[tuple[float, int]] = []
        found: Optional[int] = None
        while heap:
            negc, d = heap[0]
            if len(qs[d]) >= 1 and costs[d] == -negc:
                if d != domain and len(qs[d]) >= mv:
                    found = d
                    break
                # valid but ineligible (too shallow, or the caller's own
                # domain): set aside so the heap invariant survives
                shelved.append(heappop(heap))
            else:
                heappop(heap)   # stale: discard
        for entry in shelved:
            heappush(heap, entry)
        return found

    def _pick_victim_nearest(self, domain: int, min_victim: MinVictim,
                             topo) -> Optional[int]:
        """Nearest-first scan: tiers visited in ascending distance order,
        the configured steal order applied only within a tier.  The fast
        path skips tiers whose nonempty-peer counter is zero (no peer could
        pass any depth gate); within a tier the reference selection runs
        unchanged, so visit order and RNG draws are preserved exactly."""
        lvl = self._lvl_nonempty
        for level in range(1, topo.num_levels + 1):
            if lvl is not None and not lvl[domain][level - 1]:
                continue
            mv = self._level_min(min_victim, level)
            if mv is None:
                continue
            mv = max(mv, 1)
            if self.steal_order == "cyclic":
                for d in topo.cyclic_peers(domain, level):
                    if len(self._queues[d]) >= mv:
                        return d
                continue
            eligible = [d for d in topo.peers(domain, level)
                        if len(self._queues[d]) >= mv]
            if eligible:
                return self._pick_eligible(eligible)
        return None

    def _pick_eligible(self, eligible: list[int]) -> int:
        """Resolve a non-cyclic steal order over an eligible-victim list (a
        single tier's, or the whole machine's when flat)."""
        if self.steal_order == "longest":
            return max(eligible, key=lambda d: (len(self._queues[d]), -d))
        if self.steal_order == "cost_weighted":
            return max(eligible, key=lambda d: (self._costs[d], -d))
        return int(eligible[int(self._rng.integers(len(eligible)))])

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return self._size

    def queue_sizes(self) -> list[int]:
        return [len(q) for q in self._queues]

    def depth(self, domain: int) -> int:
        return len(self._queues[domain])

    def cost(self, domain: int) -> float:
        """Total queued cost in ``domain``'s queue (sum of enqueue-time
        cost snapshots; items without a ``cost`` attribute count 1.0)."""
        return self._costs[domain]

    def queue_costs(self) -> list[float]:
        return list(self._costs)


class SubmissionPool:
    """Bounded FIFO of submitted-but-unconsumed tasks (OpenMP §2.1).

    The cap is advisory: ``push`` never drops, but producers are expected
    to check ``full`` and switch to consuming (the paper's "the submitting
    thread is used for processing tasks for some time").
    """

    def __init__(self, cap: int = 256):
        if cap is None or cap < 1:
            raise ValueError(f"SubmissionPool cap must be >= 1, got {cap!r} "
                             "(cap=0 would make `full` permanently true)")
        self.cap = cap
        self._fifo: deque[Any] = deque()

    def push(self, item: Any) -> None:
        self._fifo.append(item)

    def pop(self) -> Optional[Any]:
        return self._fifo.popleft() if self._fifo else None

    def __len__(self) -> int:
        return len(self._fifo)

    @property
    def full(self) -> bool:
        return len(self._fifo) >= self.cap

    @property
    def free_slots(self) -> int:
        return max(self.cap - len(self._fifo), 0)
