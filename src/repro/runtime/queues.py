"""Canonical queue primitives of the locality-queue runtime.

``DomainQueues`` is the single implementation of the paper's §2.2 data
structure in this repo: one FIFO queue per locality domain, local-first
dequeue, and a steal scan over foreign queues when the local queue is dry
(balance deliberately wins over locality).  Both the offline simulator
policies (`repro.core.scheduler`) and the online serving router
(`repro.serving.engine`) route through this class — there is no second
copy of the steal-scan logic anywhere.

Four steal scans are supported:

  ``cyclic``        — the paper's scan: victims visited in domain order
                      starting right after the caller's own domain (§2.2).
  ``longest``       — steal from the deepest foreign queue (the serving
                      router's balance-first variant; ties break on lowest
                      domain id).
  ``random``        — uniform random victim among eligible queues (models
                      TBB's random stealing, §3.1); requires an ``rng``.
  ``cost_weighted`` — steal from the foreign queue holding the most queued
                      *cost* (sum of item ``cost`` attributes, 1.0 when
                      absent; ties break on lowest domain id).  With
                      heavy-tailed service costs a short queue can hide the
                      biggest backlog; this scan relieves the domain with
                      the most queued *work*, not the most queued *items*
                      (the ``repro.control`` cost-aware victim selection).

With a hierarchical ``repro.topology.DistanceMatrix`` attached, every scan
becomes *nearest-first*: victims are sought level by level (same socket,
then cross socket, then cross pod) and the configured order applies only
*within* a level — the paper's dynamic-scheduling-inside-a-domain invariant
is preserved per tier, while a worker never pays a deep-link steal when a
sibling still has eligible work.  ``min_victim`` may then be a per-level
sequence (the adaptive governor's per-level θ; a ``None`` entry forbids
that tier outright).  A flat (or absent) topology takes the original
single-tier code path untouched, RNG draws and all — flat runs are
bit-identical to the pre-topology runtime.

Queued cost is tracked per domain on every enqueue/dequeue (``cost`` /
``queue_costs``), so cost-aware routing and victim selection are O(domains)
reads, never a queue walk.

``SubmissionPool`` captures the other half of the paper's machinery: the
bounded FIFO pool of submitted-but-unconsumed tasks of OpenMP tasking
(§2.1, "the limit is set to roughly 256 tasks").  The cap is advisory —
callers consult ``full``/``free_slots`` and apply backpressure themselves
(the simulator has its submitter run a task when full; the online
``Executor`` does the same inline).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional, Sequence, Union

import numpy as np

MinVictim = Union[int, Sequence[Optional[int]]]


@dataclasses.dataclass(frozen=True)
class Popped:
    """Result of a ``DomainQueues.dequeue``.

    ``level``/``distance`` locate the steal in the topology: 0/0.0 for a
    local pop, the victim's tier and link cost for a steal (1/1.0 when no
    topology is attached — the flat machine's uniform hop).
    """

    item: Any
    domain: int        # queue the item came from
    stolen: bool       # True when it came from a foreign queue
    level: int = 0     # topology tier of the steal (0 = local)
    distance: float = 0.0   # link cost scale of the steal (0.0 = local)


class DomainQueues:
    """Per-domain FIFO queues with a local-first dequeue and a steal scan."""

    STEAL_ORDERS = ("cyclic", "longest", "random", "cost_weighted")

    def __init__(self, num_domains: int, steal_order: str = "cyclic",
                 rng: np.random.Generator | None = None,
                 topology=None):
        if num_domains < 1:
            raise ValueError("need at least one domain")
        if steal_order not in self.STEAL_ORDERS:
            raise ValueError(f"unknown steal order {steal_order!r} "
                             f"(want one of {self.STEAL_ORDERS})")
        if steal_order == "random" and rng is None:
            raise ValueError("steal_order='random' needs an rng")
        if topology is not None and topology.num_domains != num_domains:
            raise ValueError(
                f"topology covers {topology.num_domains} domains, "
                f"queues have {num_domains}")
        self.num_domains = num_domains
        self.steal_order = steal_order
        self.topology = topology
        self._rng = rng
        self._queues: list[deque[Any]] = [deque() for _ in range(num_domains)]
        self._costs: list[float] = [0.0] * num_domains
        self._size = 0

    @staticmethod
    def _item_cost(item: Any) -> float:
        return float(getattr(item, "cost", 1.0))

    # -- producer side -----------------------------------------------------
    def enqueue(self, item: Any, domain: int) -> None:
        self._queues[domain].append(item)
        self._costs[domain] += self._item_cost(item)
        self._size += 1

    # -- consumer side -----------------------------------------------------
    def dequeue(self, domain: int, *, allow_steal: bool = True,
                min_victim: MinVictim = 1) -> Optional[Popped]:
        """Pop the oldest local item; steal from a foreign queue otherwise.

        ``min_victim`` throttles stealing: only victims holding at least
        that many items are eligible (1 = the paper's greedy behaviour;
        larger values are the adaptive governor's depth threshold).  With a
        hierarchical topology it may be a per-level sequence — entry
        ``level-1`` gates that tier, ``None`` forbids it (the breaker's
        remote cut); a short sequence extends with its last entry.
        """
        if self._queues[domain]:
            return Popped(self._pop(domain), domain, False)
        if not allow_steal:
            return None
        victim = self._pick_victim(domain, min_victim)
        if victim is None:
            return None
        topo = self.topology
        if topo is None:
            level, dist = 1, 1.0
        else:
            level, dist = topo.level(domain, victim), topo.distance(domain,
                                                                    victim)
        return Popped(self._pop(victim), victim, True, level, dist)

    def _pop(self, domain: int) -> Any:
        item = self._queues[domain].popleft()
        self._size -= 1
        if self._queues[domain]:
            self._costs[domain] -= self._item_cost(item)
        else:
            self._costs[domain] = 0.0    # re-zero: no float residue on empty
        return item

    def drain(self, domain: int, n: int, budget: Optional[float] = None,
              spent: float = 0.0) -> list[Any]:
        """Pop up to ``n`` more items from ``domain``'s queue, FIFO, no steal
        scan — the executor's batch-grab primitive: after a dequeue picked a
        source queue, the rest of the batch is taken from the *same* queue so
        a batch never mixes locality domains.

        ``budget`` bounds the grab by *cost*, not just count: draining stops
        before an item that would push ``spent`` (cost already in the batch)
        past the budget.  That is the token-budget form of continuous
        batching — a grab of cheap items runs wide, one expensive item fills
        the whole budget alone — and is what makes a queue's total cost an
        honest estimate of its drain *time*.
        """
        out = []
        while n > 0 and self._queues[domain]:
            if budget is not None:
                nxt = self._item_cost(self._queues[domain][0])
                if spent + nxt > budget:
                    break
                spent += nxt
            out.append(self._pop(domain))
            n -= 1
        return out

    @staticmethod
    def _level_min(min_victim: MinVictim, level: int) -> Optional[int]:
        """The depth threshold gating ``level`` (1-based): scalar thresholds
        apply to every tier; sequences index ``level - 1`` and extend with
        their last entry.  ``None`` forbids the tier."""
        if min_victim is None or isinstance(min_victim, int):
            return min_victim
        if not len(min_victim):
            return None
        return min_victim[min(level - 1, len(min_victim) - 1)]

    def _pick_victim(self, domain: int, min_victim: MinVictim) -> Optional[int]:
        topo = self.topology
        if topo is not None and topo.hierarchical:
            return self._pick_victim_nearest(domain, min_victim, topo)
        # flat (or no) topology: the original single-tier scan, unchanged —
        # same visit order and the same RNG draw sequence, so flat runs are
        # bit-identical to the pre-topology runtime.
        mv = self._level_min(min_victim, 1)
        if mv is None:
            return None
        mv = max(mv, 1)
        if self.steal_order == "cyclic":
            for off in range(1, self.num_domains):
                d = (domain + off) % self.num_domains
                if len(self._queues[d]) >= mv:
                    return d
            return None
        eligible = [d for d in range(self.num_domains)
                    if d != domain and len(self._queues[d]) >= mv]
        if not eligible:
            return None
        return self._pick_eligible(eligible)

    def _pick_victim_nearest(self, domain: int, min_victim: MinVictim,
                             topo) -> Optional[int]:
        """Nearest-first scan: tiers visited in ascending distance order,
        the configured steal order applied only within a tier."""
        for level in range(1, topo.num_levels + 1):
            mv = self._level_min(min_victim, level)
            if mv is None:
                continue
            mv = max(mv, 1)
            if self.steal_order == "cyclic":
                for d in topo.cyclic_peers(domain, level):
                    if len(self._queues[d]) >= mv:
                        return d
                continue
            eligible = [d for d in topo.peers(domain, level)
                        if len(self._queues[d]) >= mv]
            if eligible:
                return self._pick_eligible(eligible)
        return None

    def _pick_eligible(self, eligible: list[int]) -> int:
        """Resolve a non-cyclic steal order over an eligible-victim list (a
        single tier's, or the whole machine's when flat)."""
        if self.steal_order == "longest":
            return max(eligible, key=lambda d: (len(self._queues[d]), -d))
        if self.steal_order == "cost_weighted":
            return max(eligible, key=lambda d: (self._costs[d], -d))
        return int(eligible[int(self._rng.integers(len(eligible)))])

    # -- introspection -----------------------------------------------------
    def __len__(self) -> int:
        return self._size

    def queue_sizes(self) -> list[int]:
        return [len(q) for q in self._queues]

    def depth(self, domain: int) -> int:
        return len(self._queues[domain])

    def cost(self, domain: int) -> float:
        """Total queued cost in ``domain``'s queue (sum of item ``cost``
        attributes; items without one count 1.0)."""
        return self._costs[domain]

    def queue_costs(self) -> list[float]:
        return list(self._costs)


class SubmissionPool:
    """Bounded FIFO of submitted-but-unconsumed tasks (OpenMP §2.1).

    The cap is advisory: ``push`` never drops, but producers are expected
    to check ``full`` and switch to consuming (the paper's "the submitting
    thread is used for processing tasks for some time").
    """

    def __init__(self, cap: int = 256):
        self.cap = cap
        self._fifo: deque[Any] = deque()

    def push(self, item: Any) -> None:
        self._fifo.append(item)

    def pop(self) -> Optional[Any]:
        return self._fifo.popleft() if self._fifo else None

    def __len__(self) -> int:
        return len(self._fifo)

    @property
    def full(self) -> bool:
        return len(self._fifo) >= self.cap

    @property
    def free_slots(self) -> int:
        return max(self.cap - len(self._fifo), 0)
