import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the production mesh (16x16 single-pod or 2x16x16
multi-pod), the model's abstract parameters/optimizer state/caches
(ShapeDtypeStructs — no allocation), jits the step with explicit
in/out_shardings, and runs ``.lower().compile()``.  Success proves the
sharding config is coherent; ``memory_analysis()`` proves it fits;
``cost_analysis()`` + the partitioned-HLO collective parse feed the
roofline table (EXPERIMENTS.md §Roofline).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh both --out experiments/dryrun.json
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, cell_is_applicable, get_config, list_archs
from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.sharding import ShardingRules, make_rules, use_rules
from ..models.model import build_model, param_shardings
from ..roofline.analysis import analyze, model_flops_infer, model_flops_train
from ..train.optimizer import init_opt_state, opt_state_shardings
from ..train.train_step import make_decode_step, make_prefill_step, make_train_step
from .mesh import make_production_mesh


def cell_rules(cfg: ModelConfig, shape: ShapeConfig, mesh) -> ShardingRules:
    model_size = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in data_axes:
        dp *= mesh.shape[a]
    shard_heads = cfg.num_heads > 0 and cfg.num_heads % model_size == 0
    rules = make_rules(mesh, fsdp=cfg.fsdp, shard_heads=shard_heads)
    r = dict(rules.rules)
    r["qheads"] = "model" if shard_heads else None
    r["lru"] = "model"
    r["lru_blocks"] = "model"
    r["rwkv_ffn"] = "model"
    r["zero"] = "data"
    # batch shardability per shape
    b = shape.global_batch
    if shape.kind == "train" and cfg.microbatches > 1:
        b = b // cfg.microbatches
    if b % dp != 0:
        # cannot shard batch (e.g. long_500k B=1): replicate batch, shard the
        # KV sequence over every axis instead
        r["batch"] = None
        r["kv_seq"] = tuple(a for a in ("pod", "data", "model") if a in mesh.axis_names)
    return ShardingRules(mesh=mesh, rules=r)


def batch_shardings(specs: dict, rules: ShardingRules):
    out = {}
    for name, s in specs.items():
        if name in ("tokens", "labels"):
            out[name] = rules.sharding("batch", *([None] * (len(s.shape) - 1)))
        else:  # frames / vision
            out[name] = rules.sharding("batch", None, None)
    return out


def cache_shardings(cache_specs, rules: ShardingRules):
    def leaf(path, spec):
        names = [getattr(k, "key", None) for k in path]
        name = names[-1]
        nd = len(spec.shape)
        if name in ("k", "v"):
            ax = ("batch", "kv_seq", None, None)
        elif name in ("ckv", "kr"):
            ax = ("batch", "kv_seq", None)
        elif name in ("xk", "xv"):
            ax = ("batch", None, None, None)
        elif name == "h":
            ax = ("batch", "lru")
        elif name == "conv":
            ax = ("batch", None, "lru")
        elif name in ("x_tm", "x_cm"):
            ax = ("batch", None)
        elif name == "s":
            ax = ("batch", None, None, None)
        else:
            ax = (None,) * nd
        if len(ax) < nd:  # stacked group caches
            ax = (None,) * (nd - len(ax)) + tuple(ax)
        return rules.sharding(*ax)

    return jax.tree_util.tree_map_with_path(leaf, cache_specs)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    if not ok:
        return {"status": "skipped", "reason": why}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    if shape.kind == "train" and cfg.microbatches > 1:
        # keep each microbatch shardable across the (pod, data) axes —
        # otherwise the batch silently replicates (vision-90B multi-pod
        # was 99 GiB/chip from exactly this)
        import dataclasses
        dp = mesh.size // mesh.shape["model"]
        mb = max(min(cfg.microbatches, shape.global_batch // dp), 1)
        if mb != cfg.microbatches:
            cfg = dataclasses.replace(cfg, microbatches=mb)
    rules = cell_rules(cfg, shape, mesh)
    model = build_model(cfg, max_pos=shape.seq_len)

    with jax.set_mesh(mesh), use_rules(rules):
        params_abs = model.abstract_params()
        p_sh = param_shardings(cfg, params_abs, rules)

        if shape.kind == "train":
            opt_abs = jax.eval_shape(init_opt_state, params_abs)
            o_sh = opt_state_shardings(p_sh, rules, params_abs)
            batch_abs = model.input_specs(shape)
            b_sh = batch_shardings(batch_abs, rules)
            step = make_train_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops_train(cfg.active_params(), tokens)
        elif shape.kind == "prefill":
            cache_abs = model.cache_specs(shape)
            c_sh = cache_shardings(cache_abs, rules)
            batch_abs = model.input_specs(shape)
            b_sh = batch_shardings(batch_abs, rules)
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_abs, batch_abs, cache_abs)
            mf = model_flops_infer(cfg.active_params(),
                                   shape.global_batch * shape.seq_len)
        else:  # decode
            cache_abs = model.cache_specs(shape)
            c_sh = cache_shardings(cache_abs, rules)
            tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            t_sh = rules.sharding("batch", None)
            pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
            step = make_decode_step(model)
            jitted = jax.jit(step, in_shardings=(p_sh, t_sh, None, c_sh),
                             out_shardings=(None, c_sh),
                             donate_argnums=(3,))
            lowered = jitted.lower(params_abs, tok_abs, pos_abs, cache_abs)
            mf = model_flops_infer(cfg.active_params(), shape.global_batch)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        roof = analyze(compiled)
        n_chips = mesh.size
        hlo_flops_global = roof.flops * n_chips
        result = {
            "status": "ok",
            "mesh": "multi_pod" if multi_pod else "single_pod",
            "chips": n_chips,
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory": {
                "argument_bytes_per_chip": mem.argument_size_in_bytes,
                "output_bytes_per_chip": mem.output_size_in_bytes,
                "temp_bytes_per_chip": mem.temp_size_in_bytes,
                "alias_bytes_per_chip": mem.alias_size_in_bytes,
                "peak_estimate_per_chip": mem.argument_size_in_bytes
                + mem.output_size_in_bytes + mem.temp_size_in_bytes
                - mem.alias_size_in_bytes,
            },
            "roofline": roof.as_dict(),
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / hlo_flops_global
                                   if hlo_flops_global else 0.0),
        }
        return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_path = Path(args.out)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    results = {}
    if out_path.exists() and not args.force:
        results = json.loads(out_path.read_text())

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'multi' if mp else 'single'}"
                if key in results and results[key].get("status") in ("ok", "skipped") \
                        and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[run]    {key} ...", flush=True)
                try:
                    res = run_cell(arch, shape, mp)
                except Exception as e:  # noqa: BLE001 — record and continue
                    res = {"status": "error", "error": f"{type(e).__name__}: {e}",
                           "trace": traceback.format_exc()[-2000:]}
                results[key] = res
                out_path.write_text(json.dumps(results, indent=1))
                status = res.get("status")
                extra = ""
                if status == "ok":
                    r = res["roofline"]
                    extra = (f" bottleneck={r['bottleneck']}"
                             f" t=({r['t_compute_s']:.2e},{r['t_memory_s']:.2e},"
                             f"{r['t_collective_s']:.2e})s"
                             f" mem={res['memory']['peak_estimate_per_chip']/2**30:.2f}GiB")
                elif status == "error":
                    extra = " " + res["error"][:160]
                print(f"[done]   {key}: {status}{extra}", flush=True)

    n_ok = sum(1 for r in results.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in results.values() if r.get("status") == "skipped")
    n_err = sum(1 for r in results.values() if r.get("status") == "error")
    print(f"\ntotal: {len(results)} cells — ok={n_ok} skipped={n_skip} errors={n_err}")


if __name__ == "__main__":
    main()
