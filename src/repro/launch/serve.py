"""Serving driver: batched requests through the locality-queue router.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --requests 12 --replicas 3 --policy locality

Compares router policies on the same workload (multi-turn sessions whose
follow-ups have cache affinity to the replica that served turn one) and
prints the locality/steal statistics next to the generated tokens.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from ..configs import get_config, reduce_config
from ..models.model import build_model
from ..serving.engine import Request, ServingEngine


def synth_requests(n: int, vocab: int, num_replicas: int,
                   seed: int = 0) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(8, 24))
        toks = rng.integers(0, vocab, size=plen)
        # ~2/3 of requests are session follow-ups with a cached prefix home
        home = int(rng.integers(0, num_replicas)) if rng.random() < 0.67 else -1
        reqs.append(Request(uid=i, tokens=toks, max_new=8, home_replica=home))
    return reqs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--policy", default="locality",
                    choices=["locality", "round_robin", "single_queue"])
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    model = build_model(cfg, max_pos=256)
    params = model.init_params(jax.random.key(args.seed))

    engine = ServingEngine(model, params, num_replicas=args.replicas,
                           max_seq=64, policy=args.policy)
    for req in synth_requests(args.requests, cfg.vocab_size, args.replicas,
                              seed=args.seed):
        engine.submit(req)
    done = engine.run_until_drained()
    for req in sorted(done, key=lambda r: r.uid)[:5]:
        print(f"req {req.uid:3d} -> {req.out_tokens}")
    s = engine.stats
    print(f"policy={args.policy} served={s.served} "
          f"local={s.locality_fraction:.2f} stolen={s.stolen} "
          f"prefill_tokens={s.prefill_tokens}")


if __name__ == "__main__":
    main()
