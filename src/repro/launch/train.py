"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt

``--smoke`` shrinks the arch to its reduced config (CPU-runnable); without
it the full config is used (TPU deployment).  The loop resumes from the
newest checkpoint in --ckpt automatically.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from ..configs import get_config, reduce_config
from ..data.pipeline import make_batch_iterator
from ..models.model import build_model
from ..train.loop import LoopConfig, Trainer
from ..train.optimizer import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduce_config(cfg)
    model = build_model(cfg, max_pos=max(args.seq, 128))

    data = make_batch_iterator(cfg.vocab_size, args.seq, args.batch,
                               seed=args.seed)

    # whisper / vlm smoke runs need their stub extras in every batch
    def with_extras(it):
        import numpy as np
        for batch in it:
            if cfg.encoder is not None:
                batch["frames"] = np.zeros(
                    (args.batch, cfg.encoder.num_frames, cfg.encoder.d_model),
                    np.float32)
            if cfg.vision is not None:
                batch["vision"] = np.zeros(
                    (args.batch, cfg.vision.num_image_tokens, cfg.d_model),
                    np.float32)
            yield batch

    trainer = Trainer(
        model, with_extras(data),
        LoopConfig(total_steps=args.steps, checkpoint_every=args.ckpt_every,
                   checkpoint_dir=args.ckpt, log_every=max(args.steps // 20, 1)),
        AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                    total_steps=args.steps),
    )
    out = trainer.run(seed=args.seed)
    losses = out["losses"]
    print(f"first-10 mean loss: {sum(losses[:10])/max(len(losses[:10]),1):.4f}")
    print(f"last-10  mean loss: {sum(losses[-10:])/max(len(losses[-10:]),1):.4f}")


if __name__ == "__main__":
    main()
