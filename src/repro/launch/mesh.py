"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to obtain placeholder devices.

Single pod: (16, 16) = (data, model) — 256 chips (one v5e pod).
Multi-pod:  (2, 16, 16) = (pod, data, model) — 512 chips; the "pod" axis is
the slow (DCN) tier, used for data parallelism or pipeline stages so that
bandwidth-hungry collectives stay inside a pod (the paper's locality
domains, one tier up).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for in-repo integration tests (8 host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
