# One-command entry points.  PYTHONPATH is prepended so the src/ layout
# works without an editable install.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test smoke bench trace control spec experiments topology obs \
	overhead sentinel check

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# static-analysis gate (repro.check): the determinism linter over
# src/repro/ (zero unsuppressed violations; every suppression carries a
# reason) plus the trace model checker over every committed trace fixture.
# Writes the JSON + markdown report to artifacts/ (CI uploads them).
check:
	$(PY) -m repro.check all tests/data/v1_trace_fixture.jsonl \
		tests/data/v1_segments \
		--json artifacts/check_report.json --md artifacts/check_report.md

# tier-1 + a ~10-second online-runtime benchmark: the fast reproducibility gate
smoke: test
	$(PY) -m benchmarks.runtime_throughput --fast

# the full benchmark harness (paper tables/figures + runtime)
bench:
	$(PY) -m benchmarks.run

# trace loop smoke: record -> analyze -> replay a small stencil sweep
# (repro.trace end to end), then a fast governor A/B on recorded traces
trace:
	$(PY) examples/trace_stencil.py
	$(PY) -m benchmarks.trace_replay --fast

# control-plane smoke: self-tuning serving demo (token-identity checked),
# then controlled-vs-uncontrolled replay A/B (writes BENCH_control.json)
control:
	$(PY) examples/control_serving.py
	$(PY) -m benchmarks.control_plane --fast

# spec smoke: every checked-in policy file must parse, build, and replay
# bit-identically from its own trace header, then the JSON-policy demo
spec:
	$(PY) -m repro.spec.validate specs
	$(PY) examples/spec_policies.py

# declarative-experiment gate: parse every checked-in
# specs/experiments/*.json file, build + run its declared workload end to
# end, and require header-only replay bit-identity (writes
# BENCH_experiments.json; registry/golden equality is tier-1-tested), then
# the experiment demo.  `repro.spec.validate` also accepts experiment files
# for ad-hoc validation of uncommitted ones.
experiments:
	$(PY) -m benchmarks.run --experiment all
	$(PY) examples/run_experiment.py

# topology gate: flat-vs-hierarchical stealing A/B over the checked-in
# topology experiments — asserts fewer cross-socket steals and no
# throughput loss under the two-level tree, plus header-only (schema v3)
# replay bit-identity for every arm (writes BENCH_topology.json)
topology:
	$(PY) -m benchmarks.topology_locality

# observability smoke: observe a recorded run end to end (span trees,
# registry metrics, exact p50/p95/p99, self-profiled overhead) and export
# the Perfetto timeline (artifacts/obs_timeline.perfetto-trace; CI
# uploads it)
obs:
	$(PY) examples/obs_timeline.py

# scheduler self-overhead: ns/decision for the four hot paths plus the
# obs-on/off passivity A/B, gated at <5% wall-time cost (writes
# BENCH_overhead.json).  CI runs the reduced --fast ladder; the committed
# artifact comes from the full `python -m benchmarks.scheduler_overhead`.
overhead:
	$(PY) -m benchmarks.scheduler_overhead --fast

# BENCH regression sentinel: re-run every benchmark at its committed
# baseline's own declared parameters, compare each numeric metric under
# the per-metric tolerance policy (deterministic metrics exact, wall
# metrics loose lower-is-better), write the BENCH_sentinel.md report,
# append to the BENCH_trajectory.json history, and exit nonzero on any
# regression.  Refreshing a baseline stays an explicit bench run + commit.
sentinel:
	$(PY) -m benchmarks.sentinel
