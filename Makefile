# One-command entry points.  PYTHONPATH is prepended so the src/ layout
# works without an editable install.
PY := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH)) python

.PHONY: test smoke bench

# tier-1 verify (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# tier-1 + a ~10-second online-runtime benchmark: the fast reproducibility gate
smoke: test
	$(PY) -m benchmarks.runtime_throughput --fast

# the full benchmark harness (paper tables/figures + runtime)
bench:
	$(PY) -m benchmarks.run
